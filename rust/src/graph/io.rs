//! Graph persistence: SNAP-style edge-list text and a fast binary format.
//!
//! The experiment pipeline generates the catalog analogues once
//! (`ipregel generate`) and caches them as `.ipg` binaries so repeated
//! Table II runs skip the (minutes-long) RMAT generation step.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::graph::rows::{self, Arena, RowPlane, Span};
use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"IPGRAPH1";
/// Version 2 adds optional per-edge weight arrays after each adjacency
/// array. Unweighted graphs keep writing the v1 format so existing caches
/// stay byte-identical; the reader accepts both.
const MAGIC2: &[u8; 8] = b"IPGRAPH2";
/// Out-of-core arena format (DESIGN.md §2.12): raw offsets up front, then
/// per-block spans over a delta-gap varint adjacency blob, then the raw
/// weight slabs. The blob is *not* loaded at open — `open_external` wraps
/// the file in a [`rows::RowPlane`] arena and blocks stream in on demand.
const MAGICC: &[u8; 8] = b"IPGRAPHC";

/// Write a SNAP-style edge list: `# comment` lines then `src\tdst` pairs,
/// with a third `weight` column on weighted graphs.
pub fn write_edge_list(g: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# Directed edge list written by ipregel")?;
    writeln!(w, "# Nodes: {} Edges: {}", g.num_vertices(), g.num_edges())?;
    if g.has_weights() {
        for (s, d, wt) in g.weighted_edges() {
            writeln!(w, "{s}\t{d}\t{wt}")?;
        }
    } else {
        for (s, d) in g.edges() {
            writeln!(w, "{s}\t{d}")?;
        }
    }
    Ok(())
}

/// Read a SNAP-style edge list. Accepts `#`/`%` comments, tab or space
/// separators, an optional third column (edge weight; any weighted line
/// makes the whole graph weighted, missing weights default to `1.0`), and
/// arbitrary (non-contiguous) vertex ids, which are kept as-is;
/// `num_vertices` = max id + 1. `symmetric` mirrors every edge.
pub fn read_edge_list(path: &Path, symmetric: bool) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let r = BufReader::new(f);
    let mut edges: Vec<(VertexId, VertexId, EdgeWeight)> = Vec::new();
    let mut any_weight = false;
    let mut max_id: u64 = 0;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("{}:{}: expected two ids", path.display(), lineno + 1),
        };
        let s: u64 = a
            .parse()
            .with_context(|| format!("{}:{}: bad src id", path.display(), lineno + 1))?;
        let d: u64 = b
            .parse()
            .with_context(|| format!("{}:{}: bad dst id", path.display(), lineno + 1))?;
        if s > VertexId::MAX as u64 || d > VertexId::MAX as u64 {
            bail!("{}:{}: id exceeds u32", path.display(), lineno + 1);
        }
        let w: EdgeWeight = match it.next() {
            Some(ws) => {
                let w: EdgeWeight = ws.parse().with_context(|| {
                    format!("{}:{}: bad edge weight", path.display(), lineno + 1)
                })?;
                if !w.is_finite() {
                    bail!("{}:{}: non-finite edge weight", path.display(), lineno + 1);
                }
                any_weight = true;
                w
            }
            None => 1.0,
        };
        max_id = max_id.max(s).max(d);
        edges.push((s as VertexId, d as VertexId, w));
    }
    let n = if edges.is_empty() { 0 } else { max_id as usize + 1 };
    let mut gb = GraphBuilder::new(n).symmetric(symmetric);
    if any_weight {
        for &(s, d, w) in &edges {
            gb.push_weighted_edge(s, d, w);
        }
    } else {
        for &(s, d, _) in &edges {
            gb.push_edge(s, d);
        }
    }
    Ok(gb.build())
}

/// Write the binary `.ipg` format: magic, counts, then the CSR arrays as
/// little-endian integers (plus f64 weight arrays in the v2 format).
/// ~10× faster to load than text.
pub fn write_binary(g: &Csr, path: &Path) -> Result<()> {
    if g.has_overlay() {
        bail!(
            "{}: cannot serialise a graph with a live delta overlay — \
             compact the DynamicGraph first",
            path.display()
        );
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(if g.has_weights() { MAGIC2 } else { MAGIC })?;
    write_u64(&mut w, g.num_vertices() as u64)?;
    write_u64(&mut w, g.num_edges() as u64)?;
    for off in &g.out_offsets {
        write_u64(&mut w, *off as u64)?;
    }
    write_u32_slice(&mut w, &g.out_targets)?;
    if let Some(ws) = &g.out_weights {
        write_f64_slice(&mut w, ws)?;
    }
    for off in &g.in_offsets {
        write_u64(&mut w, *off as u64)?;
    }
    write_u32_slice(&mut w, &g.in_sources)?;
    if let Some(ws) = &g.in_weights {
        write_f64_slice(&mut w, ws)?;
    }
    Ok(())
}

/// Read the binary `.ipg` format (v1 or v2) and validate the structure.
pub fn read_binary(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let weighted = if &magic == MAGIC {
        false
    } else if &magic == MAGIC2 {
        true
    } else {
        bail!("{}: not an ipgraph file", path.display());
    };
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let mut out_offsets = vec![0usize; n + 1];
    for o in &mut out_offsets {
        *o = read_u64(&mut r)? as usize;
    }
    let out_targets = read_u32_vec(&mut r, m)?;
    let out_weights = if weighted {
        Some(read_f64_vec(&mut r, m)?)
    } else {
        None
    };
    let mut in_offsets = vec![0usize; n + 1];
    for o in &mut in_offsets {
        *o = read_u64(&mut r)? as usize;
    }
    let in_sources = read_u32_vec(&mut r, m)?;
    let in_weights = if weighted {
        Some(read_f64_vec(&mut r, m)?)
    } else {
        None
    };
    let g = Csr {
        out_offsets,
        out_targets,
        in_offsets,
        in_sources,
        out_weights,
        in_weights,
        overlay: None,
        rows: None,
    };
    g.validate()
        .map_err(|e| err!("{}: corrupt graph: {e}", path.display()))?;
    Ok(g)
}

/// Load a graph by extension: `.ipg` binary, `.ipgc` out-of-core arena,
/// anything else edge-list text.
pub fn load(path: &Path, symmetric_text: bool) -> Result<Csr> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("ipg") => read_binary(path),
        Some("ipgc") => open_external(path),
        _ => read_edge_list(path, symmetric_text),
    }
}

// ------------------------------------------------- out-of-core arenas
//
// IPGRAPHC layout (all integers little-endian u64):
//
//   magic "IPGRAPHC"
//   flags                  bit 0 = weighted
//   block_size             vertices per block
//   n, m                   vertex / base-edge counts
//   out_offsets            (n+1) × u64
//   in_offsets             (n+1) × u64
//   spans                  2·num_blocks × (offset, len), blob-relative;
//                          out blocks first, then in blocks
//   blob_len
//   blob                   concatenated encoded blocks (rows.rs codec)
//   out_weights, in_weights  m × f64 each, weighted arenas only
//
// num_blocks = ceil(n / block_size) is derived, not stored. The reader
// rebases spans to absolute file offsets for the arena's positional
// reads; weights are streamed per block from the raw slabs at the tail
// (the plane serves them — `weights_in_blocks`).

/// Write the out-of-core arena file for a **raw** graph (no overlay, no
/// plane — `externalize` handles the general case). The target is
/// removed first so a fresh inode backs the new bytes: serving-layer
/// snapshot readers holding the old `File` keep reading the old
/// (unlinked) arena, never a half-rewritten one.
pub fn write_external(g: &Csr, path: &Path, block_size: usize) -> Result<()> {
    if g.has_overlay() {
        bail!(
            "{}: cannot externalise a graph with a live delta overlay — \
             compact the DynamicGraph first",
            path.display()
        );
    }
    if g.row_plane().is_some() {
        bail!(
            "{}: write_external expects raw slabs — decompress first \
             (externalize does this for you)",
            path.display()
        );
    }
    let block_size = block_size.max(1);
    let n = g.num_vertices();
    let m = g.out_targets.len();
    let num_blocks = n.div_ceil(block_size);
    let mut blob = Vec::new();
    let (mut spans, _) =
        rows::encode_blocks(&g.out_offsets, &g.out_targets, block_size, num_blocks, &mut blob);
    let (in_spans, _) =
        rows::encode_blocks(&g.in_offsets, &g.in_sources, block_size, num_blocks, &mut blob);
    spans.extend(in_spans);

    std::fs::remove_file(path).ok();
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGICC)?;
    write_u64(&mut w, u64::from(g.has_weights()))?;
    write_u64(&mut w, block_size as u64)?;
    write_u64(&mut w, n as u64)?;
    write_u64(&mut w, m as u64)?;
    for off in g.out_offsets.iter().chain(g.in_offsets.iter()) {
        write_u64(&mut w, *off as u64)?;
    }
    for s in &spans {
        write_u64(&mut w, s.offset)?;
        write_u64(&mut w, s.len)?;
    }
    write_u64(&mut w, blob.len() as u64)?;
    w.write_all(&blob)?;
    if let (Some(ow), Some(iw)) = (&g.out_weights, &g.in_weights) {
        write_f64_slice(&mut w, ow)?;
        write_f64_slice(&mut w, iw)?;
    }
    Ok(())
}

/// Open an IPGRAPHC arena: offsets load into RAM, adjacency (and
/// weights) stay on disk behind the plane's residency machinery. Only
/// structural header checks run here — a full `validate()` would decode
/// every block, defeating the point of out-of-core.
pub fn open_external(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGICC {
        bail!("{}: not an ipgraph arena file", path.display());
    }
    let weighted = read_u64(&mut r)? != 0;
    let block_size = read_u64(&mut r)? as usize;
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    if block_size == 0 {
        bail!("{}: zero block size", path.display());
    }
    let num_blocks = n.div_ceil(block_size);
    let mut out_offsets = vec![0usize; n + 1];
    for o in &mut out_offsets {
        *o = read_u64(&mut r)? as usize;
    }
    let mut in_offsets = vec![0usize; n + 1];
    for o in &mut in_offsets {
        *o = read_u64(&mut r)? as usize;
    }
    let mut spans = Vec::with_capacity(2 * num_blocks);
    for _ in 0..2 * num_blocks {
        let offset = read_u64(&mut r)?;
        let len = read_u64(&mut r)?;
        spans.push(Span { offset, len });
    }
    let blob_len = read_u64(&mut r)?;
    for (name, offs) in [("out", &out_offsets), ("in", &in_offsets)] {
        if offs[0] != 0 || *offs.last().unwrap() != m || offs.windows(2).any(|w| w[0] > w[1]) {
            bail!("{}: corrupt {name}_offsets", path.display());
        }
    }
    if spans.iter().any(|s| s.offset + s.len > blob_len) {
        bail!("{}: block span exceeds blob", path.display());
    }
    // Rebase blob-relative spans to absolute file offsets for the
    // arena's positional reads.
    let blob_base = (8 + 8 * 4 + 16 * (n + 1) + 16 * 2 * num_blocks + 8) as u64;
    for s in &mut spans {
        s.offset += blob_base;
    }
    let wbase = if weighted {
        let w0 = blob_base + blob_len;
        [w0, w0 + (m * std::mem::size_of::<EdgeWeight>()) as u64]
    } else {
        [0, 0]
    };
    let out_first: Vec<u64> = (0..=num_blocks)
        .map(|b| out_offsets[(b * block_size).min(n)] as u64)
        .collect();
    let in_first: Vec<u64> = (0..=num_blocks)
        .map(|b| in_offsets[(b * block_size).min(n)] as u64)
        .collect();
    let file = r.into_inner();
    let plane = RowPlane::new_external(
        Arena::new(file, path.to_path_buf()),
        block_size,
        n,
        weighted,
        spans,
        [out_first, in_first],
        wbase,
        blob_len,
    );
    Ok(Csr {
        out_offsets,
        out_targets: Vec::new(),
        in_offsets,
        in_sources: Vec::new(),
        out_weights: None,
        in_weights: None,
        overlay: None,
        rows: None,
    }
    .with_plane(plane))
}

/// Externalise `g` to an on-disk arena at `path` and return the
/// out-of-core view (write + reopen, so the returned graph exercises the
/// exact read path every later open uses). Accepts raw or plane-backed
/// inputs; a live overlay must be compacted first.
pub fn externalize(g: &Csr, path: &Path, block_size: usize) -> Result<Csr> {
    let decoded;
    let src = if g.row_plane().is_some() {
        decoded = g.decompressed();
        &decoded
    } else {
        g
    };
    write_external(src, path, block_size)?;
    open_external(path)
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u32_slice<W: Write>(w: &mut W, xs: &[u32]) -> std::io::Result<()> {
    // Bulk write via byte reinterpretation (LE hosts; portable fallback
    // would loop, but every deployment target here is little-endian x86).
    // SAFETY: `xs` is a live, initialised slice; viewing its memory as
    // `len * 4` bytes stays in bounds, `u8` has no alignment or validity
    // requirements, and the view is read-only for the borrow's duration.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    };
    w.write_all(bytes)
}

fn read_u32_vec<R: Read>(r: &mut R, len: usize) -> std::io::Result<Vec<u32>> {
    let mut out = vec![0u32; len];
    // SAFETY: `out` owns `len * 4` initialised bytes; the `&mut [u8]`
    // view is in bounds, uniquely borrowed from `out`, and any byte
    // pattern `read_exact` writes is a valid `u32` (LE host format).
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, len * 4)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

fn write_f64_slice<W: Write>(w: &mut W, xs: &[f64]) -> std::io::Result<()> {
    // SAFETY: as in `write_u32_slice` — in-bounds read-only byte view of
    // a live slice; `u8` imposes no alignment or validity constraints.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8)
    };
    w.write_all(bytes)
}

fn read_f64_vec<R: Read>(r: &mut R, len: usize) -> std::io::Result<Vec<f64>> {
    let mut out = vec![0f64; len];
    // SAFETY: as in `read_u32_vec` — unique in-bounds byte view of the
    // owned buffer; every 8-byte pattern is a valid `f64` bit pattern.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, len * 8)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("ipregel_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 2);
        let p = tmp("el.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, false).unwrap();
        // Round-trip may renumber nothing: same edge set.
        let mut e1: Vec<_> = g.edges().collect();
        let mut e2: Vec<_> = g2.edges().collect();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_parses_comments_and_spaces() {
        let p = tmp("comments.txt");
        std::fs::write(&p, "# header\n% other\n0 1\n1\t2\n\n2 0\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_rejects_garbage() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(read_edge_list(&p, false).is_err());
        std::fs::write(&p, "0\n").unwrap();
        assert!(read_edge_list(&p, false).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip_exact() {
        let g = gen::barabasi_albert(300, 3, 4);
        let p = tmp("g.ipg");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn weighted_text_roundtrip() {
        let g = crate::graph::GraphBuilder::new(4)
            .weighted_edges(&[(0, 1, 2.5), (1, 2, 0.125), (2, 3, 7.0), (3, 0, 1.0)])
            .build();
        let p = tmp("wel.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, false).unwrap();
        assert!(g2.has_weights());
        let mut e1: Vec<_> = g.weighted_edges().collect();
        let mut e2: Vec<_> = g2.weighted_edges().collect();
        e1.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        e2.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(e1, e2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mixed_weight_lines_default_to_one() {
        let p = tmp("mixed.txt");
        std::fs::write(&p, "0 1 2.5\n1 2\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        assert!(g.has_weights());
        assert_eq!(g.out_weights_of(0), Some(&[2.5][..]));
        assert_eq!(g.out_weights_of(1), Some(&[1.0][..]));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn weighted_binary_roundtrip_exact() {
        let base = gen::barabasi_albert(200, 3, 9);
        let g = gen::randomly_weighted(&base, 0.5, 4.5, 11);
        let p = tmp("wg.ipg");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
        assert!(g2.has_weights());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unweighted_binary_stays_v1_format() {
        let g = gen::ring(8);
        let p = tmp("v1.ipg");
        write_binary(&g, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], b"IPGRAPH1");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmp("notipg.ipg");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_dispatches_on_extension() {
        let g = gen::ring(10);
        let pb = tmp("d.ipg");
        let pt = tmp("d.txt");
        write_binary(&g, &pb).unwrap();
        write_edge_list(&g, &pt).unwrap();
        assert_eq!(load(&pb, false).unwrap(), g);
        assert_eq!(load(&pt, false).unwrap().num_edges(), g.num_edges());
        std::fs::remove_file(&pb).ok();
        std::fs::remove_file(&pt).ok();
    }

    // ------------------------------------------- out-of-core arena tests

    /// Every row of the opened arena, streamed through the plane, matches
    /// the raw slabs of the source graph.
    fn assert_same_rows(raw: &Csr, ext: &Csr) {
        assert_eq!(raw.num_vertices(), ext.num_vertices());
        assert_eq!(raw.num_edges(), ext.num_edges());
        assert_eq!(raw.has_weights(), ext.has_weights());
        for v in 0..raw.num_vertices() as VertexId {
            assert_eq!(raw.out_neighbors(v), ext.out_neighbors(v), "out v={v}");
            assert_eq!(raw.in_neighbors(v), ext.in_neighbors(v), "in v={v}");
            assert_eq!(raw.out_weights_of(v), ext.out_weights_of(v), "ow v={v}");
            assert_eq!(raw.in_weights_of(v), ext.in_weights_of(v), "iw v={v}");
        }
    }

    #[test]
    fn external_roundtrip_random_graph() {
        // RMAT leaves isolated vertices, so empty rows are covered too.
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 2);
        let p = tmp("rand.ipgc");
        for bs in [1, 7, 64, 4096] {
            let g2 = externalize(&g, &p, bs).unwrap();
            assert_eq!(g2.row_plane().unwrap().mode(), crate::graph::RowMode::External);
            assert_same_rows(&g, &g2);
            assert_eq!(g2.decompressed(), g);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn external_roundtrip_star_max_degree_row() {
        // One hub holding every edge: a single row larger than any block's
        // vertex span, plus n-1 degree-one rows.
        let n = 257u32;
        let mut gb = crate::graph::GraphBuilder::new(n as usize);
        for v in 1..n {
            gb.push_edge(0, v);
        }
        let g = gb.build();
        let p = tmp("star.ipgc");
        let g2 = externalize(&g, &p, 16).unwrap();
        assert_same_rows(&g, &g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn external_roundtrip_weighted() {
        let base = gen::barabasi_albert(200, 3, 9);
        let g = gen::randomly_weighted(&base, 0.5, 4.5, 11);
        let p = tmp("w.ipgc");
        let g2 = externalize(&g, &p, 32).unwrap();
        // Weights come out of arena blocks, not raw slabs.
        assert!(g2.row_plane().unwrap().weights_in_blocks());
        assert!(g2.out_weights.is_none());
        assert_same_rows(&g, &g2);
        assert_eq!(g2.decompressed(), g);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_dispatches_ipgc_extension() {
        let g = gen::ring(10);
        let p = tmp("d2.ipgc");
        write_external(&g, &p, 4).unwrap();
        let g2 = load(&p, false).unwrap();
        assert!(g2.row_plane().is_some());
        assert_same_rows(&g, &g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn external_rejects_bad_magic_and_truncation() {
        let p = tmp("bad.ipgc");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(open_external(&p).is_err());
        let g = gen::ring(12);
        write_external(&g, &p, 4).unwrap();
        let full = std::fs::read(&p).unwrap();
        std::fs::write(&p, &full[..full.len() / 2]).unwrap();
        assert!(open_external(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// Byte-for-byte golden pin of the row codec: varint degree prefix,
    /// then zigzag-LEB128 gaps with the first value absolute. Any codec
    /// change breaks every existing arena/compressed blob — this test is
    /// the tripwire.
    #[test]
    fn golden_row_codec_bytes() {
        let rows: [&[VertexId]; 5] = [&[1, 2], &[2], &[], &[0, 1, 2, 4], &[3]];
        let mut buf = Vec::new();
        for r in rows {
            rows::encode_row(&mut buf, r);
        }
        let expected: [u8; 13] = [
            2, 2, 2, // deg 2; zz(1) zz(1)
            1, 4, // deg 1; zz(2)
            0, // deg 0
            4, 0, 2, 2, 4, // deg 4; zz(0) zz(1) zz(1) zz(2)
            1, 6, // deg 1; zz(3)
        ];
        assert_eq!(buf, expected);
    }

    /// Full-file golden pin of the IPGRAPHC layout for a 3-cycle with
    /// block_size 2. The expected bytes are written out header field by
    /// header field, independent of the writer under test.
    #[test]
    fn golden_external_file_bytes() {
        let g = crate::graph::GraphBuilder::new(3)
            .edges(&[(0, 1), (1, 2), (2, 0)])
            .build();
        let p = tmp("golden.ipgc");
        write_external(&g, &p, 2).unwrap();
        let got = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();

        let mut want: Vec<u8> = Vec::new();
        let u = |w: &mut Vec<u8>, v: u64| w.extend_from_slice(&v.to_le_bytes());
        want.extend_from_slice(b"IPGRAPHC");
        u(&mut want, 0); // flags: unweighted
        u(&mut want, 2); // block_size
        u(&mut want, 3); // n
        u(&mut want, 3); // m
        for off in [0u64, 1, 2, 3] {
            u(&mut want, off); // out_offsets
        }
        for off in [0u64, 1, 2, 3] {
            u(&mut want, off); // in_offsets
        }
        // Spans (blob-relative): out block {v0,v1} = rows [1],[2]; out
        // block {v2} = row [0]; in block {v0,v1} = rows [2],[0]; in
        // block {v2} = row [1]. Each encoded row is 2 bytes here.
        for (off, len) in [(0u64, 4u64), (4, 2), (6, 4), (10, 2)] {
            u(&mut want, off);
            u(&mut want, len);
        }
        u(&mut want, 12); // blob_len
        want.extend_from_slice(&[
            1, 2, // out v0: [1]
            1, 4, // out v1: [2]
            1, 0, // out v2: [0]
            1, 4, // in v0: [2]
            1, 0, // in v1: [0]
            1, 2, // in v2: [1]
        ]);
        assert_eq!(got, want);
    }
}
