//! Graph persistence: SNAP-style edge-list text and a fast binary format.
//!
//! The experiment pipeline generates the catalog analogues once
//! (`ipregel generate`) and caches them as `.ipg` binaries so repeated
//! Table II runs skip the (minutes-long) RMAT generation step.

use crate::graph::builder::GraphBuilder;
use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use crate::util::error::{Context, Result};
use crate::{bail, err};
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"IPGRAPH1";
/// Version 2 adds optional per-edge weight arrays after each adjacency
/// array. Unweighted graphs keep writing the v1 format so existing caches
/// stay byte-identical; the reader accepts both.
const MAGIC2: &[u8; 8] = b"IPGRAPH2";

/// Write a SNAP-style edge list: `# comment` lines then `src\tdst` pairs,
/// with a third `weight` column on weighted graphs.
pub fn write_edge_list(g: &Csr, path: &Path) -> Result<()> {
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# Directed edge list written by ipregel")?;
    writeln!(w, "# Nodes: {} Edges: {}", g.num_vertices(), g.num_edges())?;
    if g.has_weights() {
        for (s, d, wt) in g.weighted_edges() {
            writeln!(w, "{s}\t{d}\t{wt}")?;
        }
    } else {
        for (s, d) in g.edges() {
            writeln!(w, "{s}\t{d}")?;
        }
    }
    Ok(())
}

/// Read a SNAP-style edge list. Accepts `#`/`%` comments, tab or space
/// separators, an optional third column (edge weight; any weighted line
/// makes the whole graph weighted, missing weights default to `1.0`), and
/// arbitrary (non-contiguous) vertex ids, which are kept as-is;
/// `num_vertices` = max id + 1. `symmetric` mirrors every edge.
pub fn read_edge_list(path: &Path, symmetric: bool) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let r = BufReader::new(f);
    let mut edges: Vec<(VertexId, VertexId, EdgeWeight)> = Vec::new();
    let mut any_weight = false;
    let mut max_id: u64 = 0;
    for (lineno, line) in r.lines().enumerate() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let (a, b) = match (it.next(), it.next()) {
            (Some(a), Some(b)) => (a, b),
            _ => bail!("{}:{}: expected two ids", path.display(), lineno + 1),
        };
        let s: u64 = a
            .parse()
            .with_context(|| format!("{}:{}: bad src id", path.display(), lineno + 1))?;
        let d: u64 = b
            .parse()
            .with_context(|| format!("{}:{}: bad dst id", path.display(), lineno + 1))?;
        if s > VertexId::MAX as u64 || d > VertexId::MAX as u64 {
            bail!("{}:{}: id exceeds u32", path.display(), lineno + 1);
        }
        let w: EdgeWeight = match it.next() {
            Some(ws) => {
                let w: EdgeWeight = ws.parse().with_context(|| {
                    format!("{}:{}: bad edge weight", path.display(), lineno + 1)
                })?;
                if !w.is_finite() {
                    bail!("{}:{}: non-finite edge weight", path.display(), lineno + 1);
                }
                any_weight = true;
                w
            }
            None => 1.0,
        };
        max_id = max_id.max(s).max(d);
        edges.push((s as VertexId, d as VertexId, w));
    }
    let n = if edges.is_empty() { 0 } else { max_id as usize + 1 };
    let mut gb = GraphBuilder::new(n).symmetric(symmetric);
    if any_weight {
        for &(s, d, w) in &edges {
            gb.push_weighted_edge(s, d, w);
        }
    } else {
        for &(s, d, _) in &edges {
            gb.push_edge(s, d);
        }
    }
    Ok(gb.build())
}

/// Write the binary `.ipg` format: magic, counts, then the CSR arrays as
/// little-endian integers (plus f64 weight arrays in the v2 format).
/// ~10× faster to load than text.
pub fn write_binary(g: &Csr, path: &Path) -> Result<()> {
    if g.has_overlay() {
        bail!(
            "{}: cannot serialise a graph with a live delta overlay — \
             compact the DynamicGraph first",
            path.display()
        );
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    w.write_all(if g.has_weights() { MAGIC2 } else { MAGIC })?;
    write_u64(&mut w, g.num_vertices() as u64)?;
    write_u64(&mut w, g.num_edges() as u64)?;
    for off in &g.out_offsets {
        write_u64(&mut w, *off as u64)?;
    }
    write_u32_slice(&mut w, &g.out_targets)?;
    if let Some(ws) = &g.out_weights {
        write_f64_slice(&mut w, ws)?;
    }
    for off in &g.in_offsets {
        write_u64(&mut w, *off as u64)?;
    }
    write_u32_slice(&mut w, &g.in_sources)?;
    if let Some(ws) = &g.in_weights {
        write_f64_slice(&mut w, ws)?;
    }
    Ok(())
}

/// Read the binary `.ipg` format (v1 or v2) and validate the structure.
pub fn read_binary(path: &Path) -> Result<Csr> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    let weighted = if &magic == MAGIC {
        false
    } else if &magic == MAGIC2 {
        true
    } else {
        bail!("{}: not an ipgraph file", path.display());
    };
    let n = read_u64(&mut r)? as usize;
    let m = read_u64(&mut r)? as usize;
    let mut out_offsets = vec![0usize; n + 1];
    for o in &mut out_offsets {
        *o = read_u64(&mut r)? as usize;
    }
    let out_targets = read_u32_vec(&mut r, m)?;
    let out_weights = if weighted {
        Some(read_f64_vec(&mut r, m)?)
    } else {
        None
    };
    let mut in_offsets = vec![0usize; n + 1];
    for o in &mut in_offsets {
        *o = read_u64(&mut r)? as usize;
    }
    let in_sources = read_u32_vec(&mut r, m)?;
    let in_weights = if weighted {
        Some(read_f64_vec(&mut r, m)?)
    } else {
        None
    };
    let g = Csr {
        out_offsets,
        out_targets,
        in_offsets,
        in_sources,
        out_weights,
        in_weights,
        overlay: None,
    };
    g.validate()
        .map_err(|e| err!("{}: corrupt graph: {e}", path.display()))?;
    Ok(g)
}

/// Load a graph by extension: `.ipg` binary, anything else edge-list text.
pub fn load(path: &Path, symmetric_text: bool) -> Result<Csr> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("ipg") => read_binary(path),
        _ => read_edge_list(path, symmetric_text),
    }
}

fn write_u64<W: Write>(w: &mut W, v: u64) -> std::io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn write_u32_slice<W: Write>(w: &mut W, xs: &[u32]) -> std::io::Result<()> {
    // Bulk write via byte reinterpretation (LE hosts; portable fallback
    // would loop, but every deployment target here is little-endian x86).
    // SAFETY: `xs` is a live, initialised slice; viewing its memory as
    // `len * 4` bytes stays in bounds, `u8` has no alignment or validity
    // requirements, and the view is read-only for the borrow's duration.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    };
    w.write_all(bytes)
}

fn read_u32_vec<R: Read>(r: &mut R, len: usize) -> std::io::Result<Vec<u32>> {
    let mut out = vec![0u32; len];
    // SAFETY: `out` owns `len * 4` initialised bytes; the `&mut [u8]`
    // view is in bounds, uniquely borrowed from `out`, and any byte
    // pattern `read_exact` writes is a valid `u32` (LE host format).
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, len * 4)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

fn write_f64_slice<W: Write>(w: &mut W, xs: &[f64]) -> std::io::Result<()> {
    // SAFETY: as in `write_u32_slice` — in-bounds read-only byte view of
    // a live slice; `u8` imposes no alignment or validity constraints.
    let bytes = unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 8)
    };
    w.write_all(bytes)
}

fn read_f64_vec<R: Read>(r: &mut R, len: usize) -> std::io::Result<Vec<f64>> {
    let mut out = vec![0f64; len];
    // SAFETY: as in `read_u32_vec` — unique in-bounds byte view of the
    // owned buffer; every 8-byte pattern is a valid `f64` bit pattern.
    let bytes = unsafe {
        std::slice::from_raw_parts_mut(out.as_mut_ptr() as *mut u8, len * 8)
    };
    r.read_exact(bytes)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("ipregel_test_{}_{name}", std::process::id()));
        p
    }

    #[test]
    fn edge_list_roundtrip() {
        let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 2);
        let p = tmp("el.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, false).unwrap();
        // Round-trip may renumber nothing: same edge set.
        let mut e1: Vec<_> = g.edges().collect();
        let mut e2: Vec<_> = g2.edges().collect();
        e1.sort_unstable();
        e2.sort_unstable();
        assert_eq!(e1, e2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_parses_comments_and_spaces() {
        let p = tmp("comments.txt");
        std::fs::write(&p, "# header\n% other\n0 1\n1\t2\n\n2 0\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        assert_eq!(g.num_vertices(), 3);
        assert_eq!(g.num_edges(), 3);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn edge_list_rejects_garbage() {
        let p = tmp("bad.txt");
        std::fs::write(&p, "0 x\n").unwrap();
        assert!(read_edge_list(&p, false).is_err());
        std::fs::write(&p, "0\n").unwrap();
        assert!(read_edge_list(&p, false).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_roundtrip_exact() {
        let g = gen::barabasi_albert(300, 3, 4);
        let p = tmp("g.ipg");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn weighted_text_roundtrip() {
        let g = crate::graph::GraphBuilder::new(4)
            .weighted_edges(&[(0, 1, 2.5), (1, 2, 0.125), (2, 3, 7.0), (3, 0, 1.0)])
            .build();
        let p = tmp("wel.txt");
        write_edge_list(&g, &p).unwrap();
        let g2 = read_edge_list(&p, false).unwrap();
        assert!(g2.has_weights());
        let mut e1: Vec<_> = g.weighted_edges().collect();
        let mut e2: Vec<_> = g2.weighted_edges().collect();
        e1.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        e2.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(e1, e2);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn mixed_weight_lines_default_to_one() {
        let p = tmp("mixed.txt");
        std::fs::write(&p, "0 1 2.5\n1 2\n").unwrap();
        let g = read_edge_list(&p, false).unwrap();
        assert!(g.has_weights());
        assert_eq!(g.out_weights_of(0), Some(&[2.5][..]));
        assert_eq!(g.out_weights_of(1), Some(&[1.0][..]));
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn weighted_binary_roundtrip_exact() {
        let base = gen::barabasi_albert(200, 3, 9);
        let g = gen::randomly_weighted(&base, 0.5, 4.5, 11);
        let p = tmp("wg.ipg");
        write_binary(&g, &p).unwrap();
        let g2 = read_binary(&p).unwrap();
        assert_eq!(g, g2);
        assert!(g2.has_weights());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn unweighted_binary_stays_v1_format() {
        let g = gen::ring(8);
        let p = tmp("v1.ipg");
        write_binary(&g, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], b"IPGRAPH1");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn binary_rejects_bad_magic() {
        let p = tmp("notipg.ipg");
        std::fs::write(&p, b"NOTMAGIC????????").unwrap();
        assert!(read_binary(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_dispatches_on_extension() {
        let g = gen::ring(10);
        let pb = tmp("d.ipg");
        let pt = tmp("d.txt");
        write_binary(&g, &pb).unwrap();
        write_edge_list(&g, &pt).unwrap();
        assert_eq!(load(&pb, false).unwrap(), g);
        assert_eq!(load(&pt, false).unwrap().num_edges(), g.num_edges());
        std::fs::remove_file(&pb).ok();
        std::fs::remove_file(&pt).ok();
    }
}
