//! Degree counting via message exchange — a one-round sanity algorithm
//! (every vertex sends 1 to each neighbour; the combined sum is the
//! in-degree). Exercises the sum-combiner push path end to end.

use crate::combine::SumCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Value = in-degree measured by counting received messages.
#[derive(Clone, Copy, Debug, Default)]
pub struct DegreeCount;

impl VertexProgram for DegreeCount {
    type Value = u64;
    type Message = u64;
    type Comb = SumCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> SumCombiner {
        SumCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> u64 {
        0
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        match ctx.superstep() {
            0 => ctx.broadcast(1),
            _ => {
                *ctx.value_mut() = msg.unwrap_or(0);
            }
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::Strategy;
    use crate::engine::{EngineConfig, GraphSession, RunOptions};
    use crate::graph::gen;
    use crate::layout::Layout;
    use crate::sched::Schedule;

    #[test]
    fn counts_match_csr_degrees() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 77);
        let got = GraphSession::with_config(&g, EngineConfig::default().threads(4)).run(&DegreeCount);
        for v in g.vertices() {
            assert_eq!(got.values[v as usize], g.in_degree(v) as u64, "v{v}");
        }
    }

    #[test]
    fn counts_survive_every_configuration() {
        // The full optimisation matrix must not change results — the
        // paper's core claim of user-transparent optimisation.
        let g = gen::barabasi_albert(400, 4, 3);
        let want: Vec<u64> = g.vertices().map(|v| g.in_degree(v) as u64).collect();
        // One session serves the whole matrix — the per-type store pool is
        // hit from the second configuration on.
        let session = GraphSession::new(&g);
        for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
            for layout in [Layout::Interleaved, Layout::Externalised] {
                for schedule in [
                    Schedule::Static,
                    Schedule::Dynamic { chunk: 256 },
                    Schedule::EdgeCentric,
                ] {
                    for bypass in [false, true] {
                        let cfg = EngineConfig::default()
                            .threads(4)
                            .strategy(strategy)
                            .layout(layout)
                            .schedule(schedule)
                            .bypass(bypass);
                        let got = session.run_with(&DegreeCount, RunOptions::new().config(cfg));
                        assert_eq!(
                            got.values, want,
                            "{strategy:?}/{layout:?}/{schedule:?}/bypass={bypass}"
                        );
                    }
                }
            }
        }
    }
}
