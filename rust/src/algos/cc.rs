//! Connected Components — the paper's CC benchmark.
//!
//! Min-label propagation as a single-broadcast (pull) program: every
//! vertex starts labelled with its own id, broadcasts it, and adopts the
//! minimum label heard. Converged components all carry the minimum vertex
//! id of the component. Assumes an **undirected** graph (as all of the
//! paper's Table I graphs are); on a directed graph the fixpoint is
//! forward-reachability minima, not weak components. In the paper this
//! benchmark runs on the
//! *selection bypass* iPregel version; enable it with
//! `EngineConfig::bypass(true)` (the program text is identical either way).

use crate::combine::MinCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Connected-components program. Value = current component label.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConnectedComponents;

impl VertexProgram for ConnectedComponents {
    type Value = u32;
    type Message = u32;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u32 {
        v
    }

    fn compute<C: Context<u32, u32>>(&self, ctx: &mut C, msg: Option<u32>) {
        if ctx.superstep() == 0 {
            let label = *ctx.value();
            ctx.broadcast(label);
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                ctx.broadcast(m);
            }
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::engine::{EngineConfig, GraphSession, RunOptions};
    use crate::graph::gen;

    #[test]
    fn disjoint_rings_get_distinct_labels() {
        let g = gen::disjoint_rings(4, 5);
        let got =
            GraphSession::with_config(&g, EngineConfig::default().threads(2)).run(&ConnectedComponents);
        // Component labels = min id of each ring: 0, 5, 10, 15.
        for comp in 0..4u32 {
            for v in 0..5u32 {
                assert_eq!(got.values[(comp * 5 + v) as usize], comp * 5);
            }
        }
    }

    #[test]
    fn matches_union_find_on_random_graph() {
        let g = gen::erdos_renyi(300, 350, 13);
        let got = GraphSession::new(&g).run(&ConnectedComponents);
        let want = reference::connected_components(&g);
        assert_eq!(got.values, want);
    }

    #[test]
    fn bypass_and_scan_agree() {
        let g = gen::rmat(9, 3, 0.57, 0.19, 0.19, 21);
        let session = GraphSession::new(&g);
        let scan = session.run(&ConnectedComponents);
        let bypass = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(EngineConfig::default().bypass(true)),
        );
        assert_eq!(scan.values, bypass.values);
        // Bypass must touch no *more* vertices than the scan version ran.
        assert!(bypass.metrics.total_activations() <= scan.metrics.total_activations());
    }

    #[test]
    fn single_component_converges_to_zero() {
        let g = gen::complete(20);
        let got =
            GraphSession::with_config(&g, EngineConfig::default().bypass(true)).run(&ConnectedComponents);
        assert!(got.values.iter().all(|&l| l == 0));
        // Complete graph: everyone hears 0 in superstep 1; done by 2-3.
        assert!(got.metrics.num_supersteps() <= 4);
    }
}
