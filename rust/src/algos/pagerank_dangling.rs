//! Mass-conserving PageRank via the typed global aggregator.
//!
//! The Table II benchmark ([`crate::algos::PageRank`]) drops dangling
//! (zero-out-degree) mass, as iPregel's benchmark version does. This
//! variant redistributes it uniformly using a typed [`SumAgg<f64>`]
//! aggregator: dangling vertices `contribute` their rank each superstep;
//! everyone adds `aggregated() / n` the next. Ranks then sum to exactly 1
//! — the invariant the tests pin down — and the program doubles as the
//! aggregator subsystem's end-to-end exercise (including
//! aggregator-convergence [`Halt`] policies, tested below).
//!
//! [`Halt`]: crate::engine::Halt

use crate::combine::SumCombiner;
use crate::engine::{CombinedPlane, Context, Mode, SumAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// PageRank with uniform dangling-mass redistribution.
#[derive(Clone, Debug)]
pub struct DanglingPageRank {
    /// Number of rank-update iterations.
    pub iterations: usize,
    /// Damping factor.
    pub damping: f64,
}

impl Default for DanglingPageRank {
    fn default() -> Self {
        DanglingPageRank {
            iterations: 10,
            damping: 0.85,
        }
    }
}

impl VertexProgram for DanglingPageRank {
    type Value = f64;
    type Message = f64;
    type Comb = SumCombiner;
    type Agg = SumAgg<f64>;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> SumCombiner {
        SumCombiner
    }

    fn aggregator(&self) -> SumAgg<f64> {
        SumAgg::new()
    }

    fn init(&self, g: &Csr, _v: VertexId) -> f64 {
        1.0 / g.num_vertices() as f64
    }

    fn compute<C: Context<f64, f64, f64>>(&self, ctx: &mut C, msg: Option<f64>) {
        let n = ctx.num_vertices() as f64;
        if ctx.superstep() > 0 {
            let link_mass = msg.unwrap_or(0.0);
            let dangling_mass = ctx.aggregated().copied().unwrap_or(0.0);
            *ctx.value_mut() =
                (1.0 - self.damping) / n + self.damping * (link_mass + dangling_mass / n);
        }
        if ctx.superstep() < self.iterations {
            let rank = *ctx.value();
            let deg = ctx.out_degree();
            if deg > 0 {
                ctx.broadcast(rank / deg as f64);
            } else {
                // Dangling: hand the rank to the aggregator instead.
                ctx.contribute(rank);
            }
        } else {
            ctx.vote_to_halt();
        }
    }
}

/// Serial reference with the same dangling redistribution.
pub fn reference(g: &Csr, iterations: usize, d: f64) -> Vec<f64> {
    let n = g.num_vertices();
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iterations {
        let dangling: f64 = g
            .vertices()
            .filter(|&v| g.out_degree(v) == 0)
            .map(|v| rank[v as usize])
            .sum();
        let contrib: Vec<f64> = g
            .vertices()
            .map(|v| {
                let deg = g.out_degree(v);
                if deg > 0 {
                    rank[v as usize] / deg as f64
                } else {
                    0.0
                }
            })
            .collect();
        let mut next = vec![(1.0 - d) / n as f64 + d * dangling / n as f64; n];
        for v in g.vertices() {
            let sum: f64 = g.in_neighbors(v).iter().map(|&u| contrib[u as usize]).sum();
            next[v as usize] += d * sum;
        }
        rank = next;
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, GraphSession, Halt, RunOptions};
    use crate::graph::{gen, GraphBuilder};
    use crate::layout::Layout;
    use crate::metrics::HaltReason;
    use crate::sched::Schedule;
    use crate::sim::SimEngine;

    /// Graph with dangling vertices: directed star (leaves have no
    /// out-edges) plus a ring component.
    fn graph_with_dangling() -> crate::graph::Csr {
        let mut gb = GraphBuilder::new(40);
        // 0 -> 1..20 (1..20 dangling)
        for v in 1..20 {
            gb.push_edge(0, v);
        }
        // ring over 20..40
        for v in 20..40 {
            gb.push_edge(v, 20 + (v + 1 - 20) % 20);
        }
        gb.build()
    }

    #[test]
    fn mass_is_conserved_exactly() {
        let g = graph_with_dangling();
        let r = GraphSession::with_config(&g, EngineConfig::default().threads(3))
            .run(&DanglingPageRank::default());
        let total: f64 = r.values.iter().sum();
        assert!((total - 1.0).abs() < 1e-12, "total={total}");
    }

    #[test]
    fn aggregator_convergence_halt_stops_early() {
        // Long-running variant; the dangling mass stabilises quickly, so
        // an aggregator-convergence predicate must cut the run well short
        // of the 500-iteration program bound.
        let g = graph_with_dangling();
        let session = GraphSession::new(&g);
        let p = DanglingPageRank {
            iterations: 500,
            damping: 0.85,
        };
        let r = session.run_with(
            &p,
            RunOptions::new().halt(Halt::converged(|prev: Option<&f64>, cur: Option<&f64>| {
                matches!((prev, cur), (Some(a), Some(b)) if (a - b).abs() < 1e-14)
            })),
        );
        assert_eq!(r.metrics.halt_reason, HaltReason::Converged);
        assert!(
            r.metrics.num_supersteps() < 500,
            "converged at {} supersteps",
            r.metrics.num_supersteps()
        );
        // The converged ranks still conserve mass.
        let total: f64 = r.values.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "total={total}");
    }

    #[test]
    fn matches_serial_reference() {
        let g = graph_with_dangling();
        let r = GraphSession::with_config(&g, EngineConfig::default().threads(4))
            .run(&DanglingPageRank::default());
        let want = reference(&g, 10, 0.85);
        for v in g.vertices() {
            assert!(
                (r.values[v as usize] - want[v as usize]).abs() < 1e-12,
                "v{v}: {} vs {}",
                r.values[v as usize],
                want[v as usize]
            );
        }
    }

    #[test]
    fn aggregator_works_under_every_configuration() {
        let g = gen::rmat(8, 3, 0.57, 0.19, 0.19, 19); // rmat has dangling vertices
        let want = reference(&g, 10, 0.85);
        let session = GraphSession::new(&g);
        for layout in [Layout::Interleaved, Layout::Externalised] {
            for schedule in [Schedule::Static, Schedule::Dynamic { chunk: 32 }] {
                for threads in [1, 4] {
                    let cfg = EngineConfig::default()
                        .threads(threads)
                        .layout(layout)
                        .schedule(schedule);
                    let r = session
                        .run_with(&DanglingPageRank::default(), RunOptions::new().config(cfg));
                    for v in g.vertices() {
                        assert!(
                            (r.values[v as usize] - want[v as usize]).abs() < 1e-12,
                            "v{v} {layout:?} {schedule:?} t{threads}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sim_engine_supports_aggregators() {
        let g = graph_with_dangling();
        let real = GraphSession::new(&g).run(&DanglingPageRank::default());
        let sim = SimEngine::new(&g, &DanglingPageRank::default(), EngineConfig::default()).run();
        for v in g.vertices() {
            assert!((real.values[v as usize] - sim.values[v as usize]).abs() < 1e-12);
        }
        let total: f64 = sim.values.iter().sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_dangling_vertices_means_no_aggregate() {
        // On a ring nobody contributes; aggregated() must stay None and
        // results equal the plain benchmark PageRank.
        let g = gen::ring(16);
        let session = GraphSession::new(&g);
        let a = session.run(&DanglingPageRank::default());
        let b = session.run(&crate::algos::PageRank::default());
        for v in g.vertices() {
            assert!((a.values[v as usize] - b.values[v as usize]).abs() < 1e-15);
        }
    }
}
