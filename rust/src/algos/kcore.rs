//! k-core membership: iteratively prune vertices of degree < k.
//!
//! A push-mode program with a sum combiner: a vertex that falls out of
//! the core broadcasts a removal notice; survivors decrement their
//! remaining degree by the combined count. The fixpoint marks exactly
//! the k-core (the maximal subgraph with all degrees ≥ k).

use crate::combine::SumCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Per-vertex k-core state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoreState {
    /// Still in the candidate core.
    pub alive: bool,
    /// Degree counting only still-alive neighbours.
    pub remaining_degree: u64,
}

/// k-core program.
#[derive(Clone, Copy, Debug)]
pub struct KCore {
    /// The core order `k`.
    pub k: u64,
}

impl VertexProgram for KCore {
    type Value = CoreState;
    type Message = u64;
    type Comb = SumCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> SumCombiner {
        SumCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, g: &Csr, v: VertexId) -> CoreState {
        CoreState {
            alive: true,
            remaining_degree: g.out_degree(v) as u64,
        }
    }

    fn compute<C: Context<CoreState, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let mut st = *ctx.value();
        if st.alive {
            if let Some(removed) = msg {
                st.remaining_degree = st.remaining_degree.saturating_sub(removed);
            }
            if st.remaining_degree < self.k {
                st.alive = false;
                *ctx.value_mut() = st;
                ctx.broadcast(1); // tell neighbours one more of theirs left
            } else {
                *ctx.value_mut() = st;
            }
        }
        ctx.vote_to_halt();
    }
}

/// Serial reference: repeated pruning.
pub fn kcore_reference(g: &Csr, k: u64) -> Vec<bool> {
    let n = g.num_vertices();
    let mut alive = vec![true; n];
    let mut deg: Vec<u64> = g.vertices().map(|v| g.out_degree(v) as u64).collect();
    loop {
        let mut changed = false;
        for v in 0..n {
            if alive[v] && deg[v] < k {
                alive[v] = false;
                changed = true;
                for &u in g.out_neighbors(v as VertexId) {
                    deg[u as usize] = deg[u as usize].saturating_sub(1);
                }
            }
        }
        if !changed {
            return alive;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::combine::Strategy;
    use crate::engine::{EngineConfig, GraphSession, RunOptions};
    use crate::graph::gen;

    #[test]
    fn ring_is_a_2core_but_not_3core() {
        let g = gen::ring(20);
        let session = GraphSession::new(&g);
        let r2 = session.run(&KCore { k: 2 });
        assert!(r2.values.iter().all(|s| s.alive));
        let r3 = session.run(&KCore { k: 3 });
        assert!(r3.values.iter().all(|s| !s.alive));
        assert!(r3.metrics.store_reused, "second run must recycle the store");
    }

    #[test]
    fn star_collapses_entirely_at_k2() {
        // Leaves die (degree 1), then the hub follows.
        let g = gen::star(50);
        let r = GraphSession::with_config(&g, EngineConfig::default().bypass(true))
            .run(&KCore { k: 2 });
        assert!(r.values.iter().all(|s| !s.alive));
    }

    #[test]
    fn matches_reference_on_random_graphs_all_strategies() {
        let g = gen::barabasi_albert(500, 3, 6);
        let session = GraphSession::new(&g);
        for k in [2u64, 3, 4, 5] {
            let want = kcore_reference(&g, k);
            for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
                let got = session.run_with(
                    &KCore { k },
                    RunOptions::new().config(
                        EngineConfig::default().threads(4).strategy(strategy).bypass(true),
                    ),
                );
                let got_alive: Vec<bool> = got.values.iter().map(|s| s.alive).collect();
                assert_eq!(got_alive, want, "k={k} {strategy:?}");
            }
        }
    }

    #[test]
    fn survivors_have_degree_at_least_k_within_core() {
        let g = gen::rmat(9, 6, 0.57, 0.19, 0.19, 8);
        let k = 4u64;
        let r = GraphSession::with_config(&g, EngineConfig::default().bypass(true))
            .run(&KCore { k });
        for v in g.vertices() {
            if r.values[v as usize].alive {
                let core_deg = g
                    .out_neighbors(v)
                    .iter()
                    .filter(|&&u| r.values[u as usize].alive)
                    .count() as u64;
                assert!(core_deg >= k, "v{v} core degree {core_deg}");
            }
        }
    }
}
