//! Serial reference implementations used to validate every engine
//! configuration. Straight-line, obviously-correct code — no parallelism,
//! no framework.

use crate::graph::csr::{Csr, EdgeWeight, VertexId};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Serial PageRank with the same semantics as [`crate::algos::PageRank`]:
/// `iterations` pull updates, damping `d`, dangling mass dropped.
pub fn pagerank(g: &Csr, iterations: usize, d: f64) -> Vec<f64> {
    let n = g.num_vertices();
    let mut rank = vec![1.0 / n as f64; n];
    for _ in 0..iterations {
        let contrib: Vec<f64> = g
            .vertices()
            .map(|v| {
                let deg = g.out_degree(v);
                if deg > 0 {
                    rank[v as usize] / deg as f64
                } else {
                    0.0
                }
            })
            .collect();
        let mut next = vec![(1.0 - d) / n as f64; n];
        for v in g.vertices() {
            let sum: f64 = g.in_neighbors(v).iter().map(|&u| contrib[u as usize]).sum();
            next[v as usize] += d * sum;
        }
        rank = next;
    }
    rank
}

/// Serial connected components via union-find; labels = min vertex id of
/// the component (matching min-label propagation's fixpoint).
pub fn connected_components(g: &Csr) -> Vec<u32> {
    let n = g.num_vertices();
    let mut parent: Vec<u32> = (0..n as u32).collect();
    fn find(parent: &mut [u32], mut x: u32) -> u32 {
        while parent[x as usize] != x {
            parent[x as usize] = parent[parent[x as usize] as usize];
            x = parent[x as usize];
        }
        x
    }
    for (s, d) in g.edges() {
        let (rs, rd) = (find(&mut parent, s), find(&mut parent, d));
        if rs != rd {
            // Union by min id keeps the min-label invariant directly.
            let (lo, hi) = if rs < rd { (rs, rd) } else { (rd, rs) };
            parent[hi as usize] = lo;
        }
    }
    (0..n as u32).map(|v| find(&mut parent, v)).collect()
}

/// Serial BFS levels from `root` following out-edges; `u64::MAX` =
/// unreached. Matches unweighted SSSP distances.
pub fn bfs_levels(g: &Csr, root: VertexId) -> Vec<u64> {
    let n = g.num_vertices();
    let mut level = vec![u64::MAX; n];
    if n == 0 {
        return level;
    }
    let mut q = VecDeque::new();
    level[root as usize] = 0;
    q.push_back(root);
    while let Some(v) = q.pop_front() {
        let next = level[v as usize] + 1;
        for &u in g.out_neighbors(v) {
            if level[u as usize] == u64::MAX {
                level[u as usize] = next;
                q.push_back(u);
            }
        }
    }
    level
}

/// Total-order wrapper so `f64` distances can sit in a [`BinaryHeap`].
#[derive(Clone, Copy, PartialEq)]
struct TotalF64(f64);

impl Eq for TotalF64 {}

impl PartialOrd for TotalF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TotalF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Serial Dijkstra over out-edges with non-negative weights (unit weights
/// on unweighted graphs); `f64::INFINITY` = unreached. The ground truth
/// for [`crate::algos::WeightedSssp`].
pub fn dijkstra(g: &Csr, source: VertexId) -> Vec<EdgeWeight> {
    let n = g.num_vertices();
    let mut dist = vec![f64::INFINITY; n];
    if n == 0 {
        return dist;
    }
    let mut heap: BinaryHeap<Reverse<(TotalF64, VertexId)>> = BinaryHeap::new();
    dist[source as usize] = 0.0;
    heap.push(Reverse((TotalF64(0.0), source)));
    while let Some(Reverse((TotalF64(d), v))) = heap.pop() {
        if d > dist[v as usize] {
            continue; // stale entry
        }
        for i in 0..g.out_degree(v) {
            let (u, w) = g.out_edge(v, i);
            debug_assert!(w >= 0.0, "dijkstra requires non-negative weights");
            let nd = d + w;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                heap.push(Reverse((TotalF64(nd), u)));
            }
        }
    }
    dist
}

/// Serial synchronous label propagation with the same semantics as
/// [`crate::algos::Lpa`]: `rounds` rounds; each round every vertex
/// adopts the mode of its in-neighbours' previous-round labels (ties to
/// the smallest label, via the shared [`mode_of_sorted`] core), keeping
/// its label when it has no in-neighbours.
///
/// [`mode_of_sorted`]: crate::algos::lpa::mode_of_sorted
pub fn lpa(g: &Csr, rounds: usize) -> Vec<u32> {
    let n = g.num_vertices();
    let mut labels: Vec<u32> = (0..n as u32).collect();
    let mut multiset: Vec<u32> = Vec::new();
    for _ in 0..rounds {
        let next: Vec<u32> = g
            .vertices()
            .map(|v| {
                multiset.clear();
                multiset.extend(g.in_neighbors(v).iter().map(|&u| labels[u as usize]));
                multiset.sort_unstable();
                crate::algos::lpa::mode_of_sorted(&multiset).unwrap_or(labels[v as usize])
            })
            .collect();
        labels = next;
    }
    labels
}

/// Serial per-vertex triangle counts with the same semantics as
/// [`crate::algos::Triangles`]: for every wedge `w < u < x` (edge
/// `w→u`, edge `u→x`), a closing edge `w ∈ N_out(x)` counts one
/// triangle at each of the three corners. Exactly mirrors the
/// vertex-centric enumeration (including its message multiplicities),
/// so on the contract's simple undirected graphs it counts each
/// triangle once per corner.
pub fn triangles(g: &Csr) -> Vec<u64> {
    let n = g.num_vertices();
    let mut count = vec![0u64; n];
    for u in g.vertices() {
        let lows: Vec<VertexId> = g
            .in_neighbors(u)
            .iter()
            .copied()
            .filter(|&w| w < u)
            .collect();
        if lows.is_empty() {
            continue;
        }
        for &x in g.out_neighbors(u).iter().filter(|&&x| x > u) {
            for &w in &lows {
                if g.out_neighbors(x).binary_search(&w).is_ok() {
                    count[w as usize] += 1;
                    count[u as usize] += 1;
                    count[x as usize] += 1;
                }
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::util::quick;

    #[test]
    fn bfs_on_path_is_identity() {
        let g = gen::path(6);
        assert_eq!(bfs_levels(&g, 0), vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn cc_counts_components() {
        let g = gen::disjoint_rings(5, 4);
        let labels = connected_components(&g);
        let mut uniq: Vec<u32> = labels.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq, vec![0, 4, 8, 12, 16]);
    }

    #[test]
    fn dijkstra_on_unweighted_equals_bfs() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 6);
        let root = g.max_out_degree_vertex();
        let bfs = bfs_levels(&g, root);
        let dij = dijkstra(&g, root);
        for v in g.vertices() {
            let b = bfs[v as usize];
            let d = dij[v as usize];
            if b == u64::MAX {
                assert!(d.is_infinite());
            } else {
                assert!((d - b as f64).abs() < 1e-12, "v{v}");
            }
        }
    }

    #[test]
    fn dijkstra_takes_the_cheap_path() {
        let g = crate::graph::GraphBuilder::new(4)
            .weighted_edges(&[(0, 3, 9.0), (0, 1, 2.0), (1, 2, 2.0), (2, 3, 2.0)])
            .build();
        assert_eq!(dijkstra(&g, 0), vec![0.0, 2.0, 4.0, 6.0]);
    }

    #[test]
    fn pagerank_uniform_on_regular_graph() {
        let g = gen::ring(20);
        let pr = pagerank(&g, 10, 0.85);
        for &r in &pr {
            assert!((r - 0.05).abs() < 1e-12);
        }
    }

    #[test]
    fn lpa_zero_rounds_is_identity_and_star_converges_to_hub() {
        let g = gen::star(5);
        assert_eq!(lpa(&g, 0), vec![0, 1, 2, 3, 4]);
        // Star: every leaf's only in-neighbour is the hub (0); the hub
        // sees all leaves (distinct labels → tie → smallest).
        let one = lpa(&g, 1);
        assert_eq!(one[1..], [0, 0, 0, 0]);
    }

    #[test]
    fn triangles_on_k4_is_three_per_corner() {
        let g = gen::complete(4);
        assert_eq!(triangles(&g), vec![3, 3, 3, 3]);
        assert!(triangles(&gen::ring(6)).iter().all(|&c| c == 0));
    }

    #[test]
    fn prop_cc_labels_are_component_minima() {
        quick::check("cc labels are minima", |rng| {
            let n = 2 + rng.below(60) as usize;
            let edges = quick::random_edges(rng, n, n * 2);
            let g = crate::graph::GraphBuilder::new(n)
                .symmetric(true)
                .edges(&edges)
                .build();
            let labels = connected_components(&g);
            for v in 0..n {
                // Label must be ≤ v and share v's component.
                if labels[v] > v as u32 {
                    return Err(format!("label[{v}]={} exceeds id", labels[v]));
                }
                if labels[labels[v] as usize] != labels[v] {
                    return Err(format!("label of label not fixed at {v}"));
                }
            }
            Ok(())
        });
    }
}
