//! Maximum-value propagation — the canonical Pregel paper example,
//! here as an API exercise for push mode with a max-combiner.

use crate::combine::MaxCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Every vertex converges to the maximum initial value in its weakly
/// connected component. Initial values are supplied by a seed function of
/// the vertex id.
pub struct MaxValue<F: Fn(VertexId) -> u64 + Send + Sync> {
    /// Maps vertex id → initial value.
    pub seed: F,
}

impl<F: Fn(VertexId) -> u64 + Send + Sync> VertexProgram for MaxValue<F> {
    type Value = u64;
    type Message = u64;
    type Comb = MaxCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MaxCombiner {
        MaxCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u64 {
        (self.seed)(v)
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let grew = if ctx.superstep() == 0 {
            true // everyone announces at the start
        } else if let Some(m) = msg {
            if m > *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if grew {
            let v = *ctx.value();
            ctx.broadcast(v);
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{EngineConfig, GraphSession};
    use crate::graph::gen;

    #[test]
    fn all_converge_to_component_max() {
        let g = gen::disjoint_rings(3, 7);
        let prog = MaxValue {
            seed: |v| (v as u64 * 37) % 101,
        };
        let got = GraphSession::with_config(&g, EngineConfig::default().threads(3).bypass(true))
            .run(&prog);
        for comp in 0..3u32 {
            let ids = (comp * 7)..(comp * 7 + 7);
            let want = ids.clone().map(|v| (v as u64 * 37) % 101).max().unwrap();
            for v in ids {
                assert_eq!(got.values[v as usize], want, "component {comp}");
            }
        }
    }

    #[test]
    fn already_converged_halts_fast() {
        let g = gen::ring(10);
        let prog = MaxValue { seed: |_| 5 };
        let got = GraphSession::new(&g).run(&prog);
        assert!(got.values.iter().all(|&v| v == 5));
        // Superstep 0 broadcasts, superstep 1 sees no growth, halt.
        assert!(got.metrics.num_supersteps() <= 3);
    }
}
