//! Label Propagation (community detection) — the first algorithm the
//! combined plane *cannot* express.
//!
//! Synchronous LPA: every vertex starts in its own community (label =
//! own id); each round it adopts the **mode** of its in-neighbours'
//! labels (ties broken toward the smallest label, which makes the update
//! deterministic and independent of message order). The mode of a
//! multiset is not expressible as a commutative pairwise combine into a
//! single slot — `mode({a,a,b})` cannot be reconstructed from
//! `combine(a, combine(a, b))` for any one-message `combine` — so this
//! program runs on the [`LogPlane`]: every neighbour label survives to
//! [`Context::recv`] and the vertex takes the mode of the full multiset.
//!
//! Synchronous LPA on bipartite-ish structures can oscillate between two
//! label patterns instead of converging, so the program runs a fixed
//! number of [`Lpa::rounds`] (the standard practice; a handful of rounds
//! recovers communities) and then quiesces by itself — no external
//! [`Halt`](crate::engine::Halt) policy needed. The serial reference
//! ([`crate::algos::reference::lpa`]) applies the identical update rule
//! for the identical number of rounds.

use crate::combine::NullCombiner;
use crate::engine::{Context, LogPlane, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Label-propagation program. Value = current community label.
#[derive(Clone, Copy, Debug)]
pub struct Lpa {
    /// Synchronous label-update rounds to run (each vertex broadcasts
    /// its label in rounds `0..rounds` and updates in rounds
    /// `1..=rounds`).
    pub rounds: usize,
}

impl Default for Lpa {
    /// Ten rounds — enough for community structure on the catalog-scale
    /// graphs; raise for deep, thin topologies.
    fn default() -> Self {
        Lpa { rounds: 10 }
    }
}

/// Mode of a label multiset, ties broken toward the smallest label;
/// `None` on an empty multiset. Shared verbatim between the engine
/// program and the serial reference so the two cannot diverge in
/// tie-breaking. Allocation-free wrappers below feed it: the compute
/// hot path sorts into a per-thread scratch buffer.
pub fn mode_of_sorted(sorted: &[u32]) -> Option<u32> {
    if sorted.is_empty() {
        return None;
    }
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]));
    let mut best = sorted[0];
    let mut best_count = 0usize;
    let mut i = 0;
    while i < sorted.len() {
        let mut j = i + 1;
        while j < sorted.len() && sorted[j] == sorted[i] {
            j += 1;
        }
        // Strict '>' keeps the first (smallest) label on count ties.
        if j - i > best_count {
            best_count = j - i;
            best = sorted[i];
        }
        i = j;
    }
    Some(best)
}

/// [`mode_of_sorted`] over an unsorted multiset, sorting into a
/// caller-owned scratch buffer (no per-call allocation once the scratch
/// has warmed up).
pub fn mode_label_into(labels: &[u32], scratch: &mut Vec<u32>) -> Option<u32> {
    scratch.clear();
    scratch.extend_from_slice(labels);
    scratch.sort_unstable();
    mode_of_sorted(scratch)
}

/// Convenience form of [`mode_label_into`] with a throwaway buffer.
pub fn mode_label(labels: &[u32]) -> Option<u32> {
    mode_label_into(labels, &mut Vec::new())
}

impl VertexProgram for Lpa {
    type Value = u32;
    type Message = u32;
    type Comb = NullCombiner;
    type Agg = NoAgg;
    type Delivery = LogPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> NullCombiner {
        NullCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u32 {
        v
    }

    fn compute<C: Context<u32, u32>>(&self, ctx: &mut C, _msg: Option<u32>) {
        if ctx.superstep() > 0 {
            // Per-worker scratch: the mode needs a sorted copy of the
            // inbox, and allocating one per vertex per round would be
            // the dominant cost of the compute phase.
            thread_local! {
                static SCRATCH: std::cell::RefCell<Vec<u32>> =
                    std::cell::RefCell::new(Vec::new());
            }
            let label = SCRATCH.with(|s| mode_label_into(ctx.recv(), &mut s.borrow_mut()));
            if let Some(label) = label {
                *ctx.value_mut() = label;
            }
        }
        if ctx.superstep() < self.rounds {
            // Every vertex republishes every round — the full neighbour
            // multiset is what the mode is defined over, so staying
            // active (not halting) until the final round is part of the
            // algorithm, not an inefficiency.
            let label = *ctx.value();
            ctx.broadcast(label);
        } else {
            ctx.vote_to_halt();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::engine::{EngineConfig, GraphSession};
    use crate::graph::gen;
    use crate::metrics::DeliveryPlaneKind;

    #[test]
    fn mode_label_takes_majority_and_breaks_ties_low() {
        assert_eq!(mode_label(&[]), None);
        assert_eq!(mode_label(&[5]), Some(5));
        assert_eq!(mode_label(&[3, 7, 3]), Some(3));
        assert_eq!(mode_label(&[7, 3, 7, 3]), Some(3), "tie -> smallest");
        assert_eq!(mode_label(&[9, 9, 1, 2, 9, 1]), Some(9));
        // The scratch-reusing form agrees and leaves the buffer reusable.
        let mut scratch = Vec::new();
        assert_eq!(mode_label_into(&[7, 3, 7, 3], &mut scratch), Some(3));
        assert_eq!(mode_label_into(&[4], &mut scratch), Some(4));
        assert_eq!(mode_label_into(&[], &mut scratch), None);
        assert_eq!(mode_of_sorted(&[1, 2, 2, 9]), Some(2));
    }

    #[test]
    fn two_cliques_with_a_bridge_get_two_communities() {
        // Two K5s joined by one edge: LPA must settle each clique on one
        // label and not bleed across the bridge.
        let mut edges = Vec::new();
        for a in 0..5u32 {
            for b in 0..5u32 {
                if a != b {
                    edges.push((a, b));
                    edges.push((a + 5, b + 5));
                }
            }
        }
        edges.push((4, 5));
        edges.push((5, 4));
        let g = crate::graph::GraphBuilder::new(10).dedup(true).edges(&edges).build();
        let r = GraphSession::with_config(&g, EngineConfig::default().threads(3))
            .run(&Lpa::default());
        assert_eq!(r.metrics.delivery_plane, DeliveryPlaneKind::Log);
        let left = r.values[0];
        let right = r.values[9];
        for v in 0..5 {
            assert_eq!(r.values[v], left, "left clique split");
        }
        for v in 5..10 {
            assert_eq!(r.values[v], right, "right clique split");
        }
        assert_ne!(left, right, "bridge bled a label across");
    }

    #[test]
    fn matches_serial_reference_and_quiesces_by_itself() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 31);
        let p = Lpa { rounds: 6 };
        let r = GraphSession::with_config(&g, EngineConfig::default().threads(4)).run(&p);
        assert_eq!(r.values, reference::lpa(&g, 6));
        // rounds broadcast supersteps + one final update-only superstep.
        assert_eq!(r.metrics.num_supersteps(), 7);
        assert_eq!(
            r.metrics.halt_reason,
            crate::metrics::HaltReason::Quiescence
        );
        // Every payload is retained — nothing may be folded on this plane.
        assert_eq!(r.metrics.retained_messages, r.metrics.total_messages());
        assert_eq!(r.metrics.combined_messages, 0);
    }
}
