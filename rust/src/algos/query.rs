//! Bounded-scope query programs for the serving layer (`serve/`).
//!
//! The Table II benchmarks sweep whole graphs; a serving workload is
//! dominated by *point lookups* — "the 2-hop neighbourhood of this
//! vertex", "everything within cost 6 of this depot", "which ranks moved
//! most since the last batch". These programs are the bounded-scope
//! twins of [`crate::algos::Bfs`] / [`crate::algos::WeightedSssp`] /
//! [`crate::algos::PageRank`]: identical propagation rules, plus one
//! scope bound that keeps the frontier (and therefore latency) local to
//! the query instead of proportional to the graph.
//!
//! Per the paper's programmability thesis the bound lives in the
//! *algorithm* (a radius/cutoff test before broadcasting), never in the
//! engine: the same `compute` text runs under every engine
//! configuration, so a served query is bit-identical to the same program
//! run solo — the invariant `rust/tests/test_serve.rs` pins down.

use crate::combine::MinCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Level value for vertices outside the ego net (shared with
/// [`crate::algos::UNREACHED`] — both are BFS levels).
pub const OUTSIDE: u64 = u64::MAX;

/// Ego-network BFS: levels out to `radius` hops from `root`, [`OUTSIDE`]
/// beyond. The frontier dies after `radius` waves no matter how large
/// the graph is, so the superstep count — and the token bill the serving
/// layer charges — is bounded by the query, not the graph.
#[derive(Clone, Copy, Debug)]
pub struct EgoNetBfs {
    /// Ego vertex.
    pub root: VertexId,
    /// Hop bound: vertices at level ≤ `radius` are inside the net.
    pub radius: u64,
}

impl VertexProgram for EgoNetBfs {
    type Value = u64;
    type Message = u64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u64 {
        if v == self.root {
            0
        } else {
            OUTSIDE
        }
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        v == self.root
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let improved = if ctx.superstep() == 0 && ctx.id() == self.root {
            true
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        // The scope bound: the wave stops at the radius. Everything else
        // is Sssp::compute verbatim.
        if improved && *ctx.value() < self.radius {
            let next = *ctx.value() + 1;
            ctx.broadcast(next);
        }
        ctx.vote_to_halt();
    }
}

/// Point-to-region shortest paths: weighted distances from `source` out
/// to cost `cutoff`, `f64::INFINITY` beyond. With non-negative weights
/// every prefix of a shortest path is itself shortest, so truncating
/// relaxation at the cutoff loses nothing inside the region — the
/// reference check below is literally Dijkstra with far entries masked.
#[derive(Clone, Copy, Debug)]
pub struct PointSssp {
    /// Query origin.
    pub source: VertexId,
    /// Cost bound: distances ≤ `cutoff` are reported exactly.
    pub cutoff: f64,
}

impl VertexProgram for PointSssp {
    type Value = f64;
    type Message = f64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> f64 {
        if v == self.source {
            0.0
        } else {
            f64::INFINITY
        }
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        v == self.source
    }

    fn compute<C: Context<f64, f64>>(&self, ctx: &mut C, msg: Option<f64>) {
        let improved = if ctx.superstep() == 0 && ctx.id() == self.source {
            true
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if improved {
            let dist = *ctx.value();
            for i in 0..ctx.out_degree() {
                let (dst, w) = ctx.out_edge(i);
                let next = dist + w;
                // The scope bound: labels past the cutoff are never sent.
                if next <= self.cutoff {
                    ctx.send(dst, next);
                }
            }
        }
        ctx.vote_to_halt();
    }
}

/// The `k` vertices whose PageRank moved most between two rank vectors
/// (e.g. before/after a mutation batch), ranked by `|new - old|`
/// descending, ties broken by vertex id. The serving layer's "what
/// changed" query: two short PageRank runs plus this O(n log n) scan.
pub fn top_k_deltas(old: &[f64], new: &[f64], k: usize) -> Vec<(VertexId, f64)> {
    let n = old.len().min(new.len());
    let mut deltas: Vec<(VertexId, f64)> = (0..n)
        .map(|v| (v as VertexId, (new[v] - old[v]).abs()))
        .collect();
    deltas.sort_by(|a, b| {
        b.1.partial_cmp(&a.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    deltas.truncate(k);
    deltas
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::engine::{EngineConfig, GraphSession, RunOptions};
    use crate::graph::gen;

    /// Serial reference: full BFS levels with everything past `radius`
    /// masked to [`OUTSIDE`].
    fn ego_reference(g: &Csr, root: VertexId, radius: u64) -> Vec<u64> {
        reference::bfs_levels(g, root)
            .into_iter()
            .map(|l| if l <= radius { l } else { OUTSIDE })
            .collect()
    }

    /// Serial reference: Dijkstra with far entries masked to infinity.
    fn point_reference(g: &Csr, source: VertexId, cutoff: f64) -> Vec<f64> {
        reference::dijkstra(g, source)
            .into_iter()
            .map(|d| if d <= cutoff { d } else { f64::INFINITY })
            .collect()
    }

    #[test]
    fn ego_net_matches_truncated_bfs() {
        let g = gen::rmat(9, 4, 0.57, 0.19, 0.19, 31);
        let root = g.max_out_degree_vertex();
        for radius in [0u64, 1, 2, 3] {
            let want = ego_reference(&g, root, radius);
            let got = GraphSession::new(&g).run(&EgoNetBfs { root, radius });
            assert_eq!(got.values, want, "radius {radius}");
            // The wave bound: radius + a final echo-only superstep at most.
            assert!(
                got.metrics.num_supersteps() as u64 <= radius + 2,
                "radius {radius}: {} supersteps",
                got.metrics.num_supersteps()
            );
        }
    }

    #[test]
    fn ego_net_radius_zero_is_just_the_root() {
        let g = gen::path(6);
        let got = GraphSession::new(&g).run(&EgoNetBfs { root: 2, radius: 0 });
        let want: Vec<u64> = (0..6).map(|v| if v == 2 { 0 } else { OUTSIDE }).collect();
        assert_eq!(got.values, want);
    }

    #[test]
    fn point_sssp_matches_truncated_dijkstra() {
        for seed in [3u64, 11] {
            let base = gen::rmat(8, 4, 0.57, 0.19, 0.19, seed);
            let g = gen::randomly_weighted(&base, 0.25, 8.0, seed ^ 0x5EED);
            let source = g.max_out_degree_vertex();
            for cutoff in [0.5, 4.0, 16.0] {
                let want = point_reference(&g, source, cutoff);
                let got = GraphSession::new(&g).run_with(
                    &PointSssp { source, cutoff },
                    RunOptions::new()
                        .config(EngineConfig::default().threads(4).bypass(true)),
                );
                for v in g.vertices() {
                    let (a, b) = (got.values[v as usize], want[v as usize]);
                    assert!(
                        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
                        "seed {seed} cutoff {cutoff} v{v}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn top_k_deltas_ranks_by_magnitude_then_id() {
        let old = [0.25, 0.25, 0.25, 0.25];
        let new = [0.10, 0.30, 0.40, 0.20];
        let got = top_k_deltas(&old, &new, 3);
        assert_eq!(got.len(), 3);
        // |Δ| = [0.15, 0.05, 0.15, 0.05]: the two 0.15s lead, id order.
        assert_eq!(got[0].0, 0);
        assert!((got[0].1 - 0.15).abs() < 1e-12);
        assert_eq!(got[1].0, 2);
        assert_eq!(got[2].0, 1);
        let got_tie = top_k_deltas(&[0.0, 0.0], &[0.5, 0.5], 2);
        assert_eq!(got_tie[0].0, 0, "ties break by vertex id");
        assert_eq!(got_tie[1].0, 1);
    }
}
