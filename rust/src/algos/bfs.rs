//! Breadth-first search levels + parents (extra API exercise: push mode
//! with a compound value).

use crate::combine::MinCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Per-vertex BFS state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BfsState {
    /// BFS level (u32::MAX = unreached).
    pub level: u32,
    /// Discovering parent (u32::MAX = none/root).
    pub parent: VertexId,
}

/// BFS program. Messages encode `(level+1) << 32 | sender` so the min
/// combiner picks the lowest level and, within a level, the lowest parent
/// id — a deterministic parent assignment under any thread interleaving.
#[derive(Clone, Copy, Debug)]
pub struct Bfs {
    /// Root vertex.
    pub root: VertexId,
}

impl VertexProgram for Bfs {
    type Value = BfsState;
    type Message = u64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> BfsState {
        if v == self.root {
            BfsState {
                level: 0,
                parent: VertexId::MAX,
            }
        } else {
            BfsState {
                level: u32::MAX,
                parent: VertexId::MAX,
            }
        }
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        v == self.root
    }

    fn compute<C: Context<BfsState, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let discovered = if ctx.superstep() == 0 && ctx.id() == self.root {
            true
        } else if let Some(m) = msg {
            let level = (m >> 32) as u32;
            let parent = (m & 0xFFFF_FFFF) as VertexId;
            if level < ctx.value().level {
                *ctx.value_mut() = BfsState { level, parent };
                true
            } else {
                false
            }
        } else {
            false
        };
        if discovered {
            let my_level = ctx.value().level;
            let me = ctx.id() as u64;
            ctx.broadcast(((my_level as u64 + 1) << 32) | me);
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::engine::{EngineConfig, GraphSession};
    use crate::graph::gen;

    #[test]
    fn levels_match_reference() {
        let g = gen::rmat(8, 3, 0.57, 0.19, 0.19, 31);
        let root = g.max_out_degree_vertex();
        let got = GraphSession::with_config(&g, EngineConfig::default().bypass(true))
            .run(&Bfs { root });
        let want = reference::bfs_levels(&g, root);
        for v in g.vertices() {
            let lvl = got.values[v as usize].level;
            let want_lvl = want[v as usize];
            let got64 = if lvl == u32::MAX { u64::MAX } else { lvl as u64 };
            assert_eq!(got64, want_lvl, "v{v}");
        }
    }

    #[test]
    fn parents_are_consistent() {
        let g = gen::grid(6, 6);
        let got =
            GraphSession::with_config(&g, EngineConfig::default().threads(4)).run(&Bfs { root: 0 });
        for v in g.vertices() {
            let st = got.values[v as usize];
            if v == 0 {
                assert_eq!(st.level, 0);
                continue;
            }
            // Parent must be a real in-neighbour one level up.
            let p = st.parent;
            assert!(g.in_neighbors(v).contains(&p), "v{v} parent {p}");
            assert_eq!(got.values[p as usize].level + 1, st.level);
        }
    }

    #[test]
    fn deterministic_parent_under_threads() {
        let g = gen::complete(12);
        let session = GraphSession::new(&g);
        let a = session.run_with(
            &Bfs { root: 3 },
            crate::engine::RunOptions::new().config(EngineConfig::default().threads(1)),
        );
        let b = session.run_with(
            &Bfs { root: 3 },
            crate::engine::RunOptions::new().config(EngineConfig::default().threads(8)),
        );
        for v in g.vertices() {
            assert_eq!(a.values[v as usize], b.values[v as usize]);
        }
    }
}
