//! The benchmark algorithms, written against the public Pregel API.
//!
//! These are the paper's three benchmarks — PageRank (pull
//! single-broadcast), Connected Components (pull + selection bypass) and
//! unweighted SSSP (push + combiner + selection bypass) — plus smaller
//! programs exercising other corners of the API: weighted shortest paths
//! ([`WeightedSssp`], via `Context::out_edge`), typed aggregators
//! ([`DanglingPageRank`]), and warm-started, epoch-validated incremental
//! recomputation over evolving graphs ([`IncrementalCc`],
//! [`IncrementalWsssp`], [`DeltaPageRank`] — see
//! [`incremental`]), bounded-scope serving queries whose frontier is
//! local to the query rather than the graph ([`EgoNetBfs`],
//! [`PointSssp`], [`top_k_deltas`] — see [`query`] and `serve/`), and
//! two **non-combinable** programs that need the
//! log delivery plane's full message multisets ([`Lpa`] label
//! propagation and [`Triangles`] per-vertex triangle counting — see
//! `combine/plane.rs`). Per the paper's programmability thesis, **no
//! algorithm references any optimisation**: the same `compute` text runs
//! under every engine configuration.

pub mod bfs;
pub mod cc;
pub mod degree;
pub mod incremental;
pub mod kcore;
pub mod lpa;
pub mod maxval;
pub mod pagerank;
pub mod pagerank_dangling;
pub mod query;
pub mod reference;
pub mod sssp;
pub mod triangles;

pub use bfs::Bfs;
pub use cc::ConnectedComponents;
pub use degree::DegreeCount;
pub use incremental::{
    DeltaPageRank, IncrementalCc, IncrementalState, IncrementalWsssp,
};
pub use kcore::{CoreState, KCore};
pub use lpa::Lpa;
pub use maxval::MaxValue;
pub use pagerank::PageRank;
pub use pagerank_dangling::DanglingPageRank;
pub use query::{top_k_deltas, EgoNetBfs, PointSssp};
pub use sssp::{Sssp, WeightedSssp, UNREACHED};
pub use triangles::Triangles;
