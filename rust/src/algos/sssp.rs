//! Single-Source Shortest Path — the paper's SSSP benchmark.
//!
//! Unweighted (every edge costs 1), push-based: distance improvements are
//! *sent* to out-neighbours and merged by a min-combiner in the recipient
//! mailbox. This is the benchmark where the hybrid combiner (§III)
//! applies — PR and CC use the lock-free pull version instead.

use crate::combine::MinCombiner;
use crate::engine::{Context, Mode, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Distance value for unreached vertices.
pub const UNREACHED: u64 = u64::MAX;

/// SSSP program. Value = current best distance from the source.
#[derive(Clone, Copy, Debug)]
pub struct Sssp {
    /// Source vertex. The Table II experiments source from the
    /// max-out-degree vertex so the traversal covers the giant component.
    pub source: VertexId,
}

impl Sssp {
    /// SSSP from the graph's maximum-degree hub (the experiment default).
    pub fn from_hub(g: &Csr) -> Self {
        Sssp {
            source: g.max_out_degree_vertex(),
        }
    }
}

impl VertexProgram for Sssp {
    type Value = u64;
    type Message = u64;
    type Comb = MinCombiner;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u64 {
        if v == self.source {
            0
        } else {
            UNREACHED
        }
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        v == self.source
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let improved = if ctx.superstep() == 0 && ctx.id() == self.source {
            true // seed the frontier
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if improved {
            let next = *ctx.value() + 1;
            ctx.broadcast(next);
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::combine::Strategy;
    use crate::engine::{run, EngineConfig};
    use crate::graph::gen;

    #[test]
    fn path_graph_distances_are_positions() {
        let g = gen::path(10);
        let got = run(&g, &Sssp { source: 0 }, EngineConfig::default().bypass(true));
        for v in 0..10 {
            assert_eq!(got.values[v], v as u64);
        }
    }

    #[test]
    fn matches_bfs_reference_all_strategies() {
        let g = gen::rmat(9, 4, 0.57, 0.19, 0.19, 17);
        let p = Sssp::from_hub(&g);
        let want = reference::bfs_levels(&g, p.source);
        for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
            let got = run(
                &g,
                &p,
                EngineConfig::default()
                    .threads(4)
                    .strategy(strategy)
                    .bypass(true),
            );
            assert_eq!(got.values, want, "{strategy:?}");
        }
    }

    #[test]
    fn unreachable_vertices_stay_unreached() {
        let g = gen::disjoint_rings(2, 4); // two components
        let got = run(&g, &Sssp { source: 0 }, EngineConfig::default());
        for v in 0..4 {
            assert!(got.values[v] < UNREACHED);
        }
        for v in 4..8 {
            assert_eq!(got.values[v], UNREACHED);
        }
    }

    #[test]
    fn frontier_sizes_trace_bfs_waves() {
        let g = gen::path(50);
        let got = run(&g, &Sssp { source: 0 }, EngineConfig::default().bypass(true));
        // Path: each wave advances one hop; the frontier holds the new
        // vertex plus the (non-improving) echo back to its predecessor.
        for s in &got.metrics.supersteps {
            assert!(s.active_vertices <= 2, "{}", s.active_vertices);
        }
        // 49 hops + the final echo-only superstep.
        assert!(
            (50..=51).contains(&got.metrics.num_supersteps()),
            "{}",
            got.metrics.num_supersteps()
        );
    }
}
