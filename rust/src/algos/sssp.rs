//! Single-Source Shortest Path — the paper's SSSP benchmark, plus the
//! weighted generalisation the v2 API unlocks.
//!
//! [`Sssp`] is the paper's version: unweighted (every edge costs 1),
//! push-based, distance improvements *sent* to out-neighbours and merged
//! by a min-combiner in the recipient mailbox. This is the benchmark
//! where the hybrid combiner (§III) applies — PR and CC use the
//! lock-free pull version instead.
//!
//! [`WeightedSssp`] runs the same wavefront with real edge weights via
//! [`Context::out_edge`] (Bellman-Ford-style label correcting under the
//! Pregel model). On an unweighted graph every weight reads as `1.0`, so
//! it degenerates to BFS distances; results are validated against a
//! serial Dijkstra reference.

use crate::combine::MinCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Distance value for unreached vertices.
pub const UNREACHED: u64 = u64::MAX;

/// SSSP program. Value = current best distance from the source.
#[derive(Clone, Copy, Debug)]
pub struct Sssp {
    /// Source vertex. The Table II experiments source from the
    /// max-out-degree vertex so the traversal covers the giant component.
    pub source: VertexId,
}

impl Sssp {
    /// SSSP from the graph's maximum-degree hub (the experiment default).
    pub fn from_hub(g: &Csr) -> Self {
        Sssp {
            source: g.max_out_degree_vertex(),
        }
    }
}

impl VertexProgram for Sssp {
    type Value = u64;
    type Message = u64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u64 {
        if v == self.source {
            0
        } else {
            UNREACHED
        }
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        v == self.source
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let improved = if ctx.superstep() == 0 && ctx.id() == self.source {
            true // seed the frontier
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if improved {
            let next = *ctx.value() + 1;
            ctx.broadcast(next);
        }
        ctx.vote_to_halt();
    }
}

/// Weighted SSSP. Value = current best distance (`f64::INFINITY` =
/// unreached). Requires non-negative edge weights — a negative weight
/// panics during run initialisation (label-correcting propagation would
/// oscillate or return wrong distances, and the serial Dijkstra
/// reference is invalid there).
#[derive(Clone, Copy, Debug)]
pub struct WeightedSssp {
    /// Source vertex.
    pub source: VertexId,
}

impl WeightedSssp {
    /// Weighted SSSP from the graph's maximum-degree hub.
    pub fn from_hub(g: &Csr) -> Self {
        WeightedSssp {
            source: g.max_out_degree_vertex(),
        }
    }
}

impl VertexProgram for WeightedSssp {
    type Value = f64;
    type Message = f64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, g: &Csr, v: VertexId) -> f64 {
        // Release-mode guard, paid once per run (init visits each vertex
        // exactly once, so this totals one O(E) sweep): IO/builder only
        // reject non-finite weights, and label-correcting relaxation
        // returns wrong distances on negative ones.
        if let Some(ws) = g.out_weights_of(v) {
            if let Some(w) = ws.iter().find(|w| **w < 0.0) {
                panic!(
                    "WeightedSssp requires non-negative edge weights; \
                     vertex {v} has an out-edge of weight {w}"
                );
            }
        }
        if v == self.source {
            0.0
        } else {
            f64::INFINITY
        }
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        v == self.source
    }

    fn compute<C: Context<f64, f64>>(&self, ctx: &mut C, msg: Option<f64>) {
        let improved = if ctx.superstep() == 0 && ctx.id() == self.source {
            true
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if improved {
            // Per-edge relaxation: each neighbour gets dist + its own edge
            // weight, so this cannot use broadcast() — this loop is what
            // Context::out_edge exists for.
            let dist = *ctx.value();
            for i in 0..ctx.out_degree() {
                let (dst, w) = ctx.out_edge(i);
                debug_assert!(w >= 0.0, "negative weight reached relaxation");
                ctx.send(dst, dist + w);
            }
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::combine::Strategy;
    use crate::engine::{EngineConfig, GraphSession};
    use crate::graph::gen;

    #[test]
    fn path_graph_distances_are_positions() {
        let g = gen::path(10);
        let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
        let got = session.run(&Sssp { source: 0 });
        for v in 0..10 {
            assert_eq!(got.values[v], v as u64);
        }
    }

    #[test]
    fn matches_bfs_reference_all_strategies() {
        let g = gen::rmat(9, 4, 0.57, 0.19, 0.19, 17);
        let p = Sssp::from_hub(&g);
        let want = reference::bfs_levels(&g, p.source);
        let session = GraphSession::new(&g);
        for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
            let got = session.run_with(
                &p,
                crate::engine::RunOptions::new().config(
                    EngineConfig::default()
                        .threads(4)
                        .strategy(strategy)
                        .bypass(true),
                ),
            );
            assert_eq!(got.values, want, "{strategy:?}");
        }
    }

    #[test]
    fn unreachable_vertices_stay_unreached() {
        let g = gen::disjoint_rings(2, 4); // two components
        let got = GraphSession::new(&g).run(&Sssp { source: 0 });
        for v in 0..4 {
            assert!(got.values[v] < UNREACHED);
        }
        for v in 4..8 {
            assert_eq!(got.values[v], UNREACHED);
        }
    }

    #[test]
    fn frontier_sizes_trace_bfs_waves() {
        let g = gen::path(50);
        let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
        let got = session.run(&Sssp { source: 0 });
        // Path: each wave advances one hop; the frontier holds the new
        // vertex plus the (non-improving) echo back to its predecessor.
        for s in &got.metrics.supersteps {
            assert!(s.active_vertices <= 2, "{}", s.active_vertices);
        }
        // 49 hops + the final echo-only superstep.
        assert!(
            (50..=51).contains(&got.metrics.num_supersteps()),
            "{}",
            got.metrics.num_supersteps()
        );
    }

    #[test]
    fn weighted_matches_dijkstra_on_random_weighted_graphs() {
        for seed in [1u64, 5, 9] {
            let base = gen::rmat(8, 4, 0.57, 0.19, 0.19, seed);
            let g = gen::randomly_weighted(&base, 0.25, 8.0, seed ^ 0xABCD);
            let p = WeightedSssp::from_hub(&g);
            let want = reference::dijkstra(&g, p.source);
            let session = GraphSession::new(&g);
            for strategy in [Strategy::Lock, Strategy::Hybrid] {
                let got = session.run_with(
                    &p,
                    crate::engine::RunOptions::new().config(
                        EngineConfig::default()
                            .threads(4)
                            .strategy(strategy)
                            .bypass(true),
                    ),
                );
                for v in g.vertices() {
                    let (a, b) = (got.values[v as usize], want[v as usize]);
                    assert!(
                        (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
                        "seed {seed} v{v}: {a} vs {b} under {strategy:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_on_unweighted_graph_equals_bfs() {
        let g = gen::rmat(8, 3, 0.57, 0.19, 0.19, 23);
        let p = WeightedSssp::from_hub(&g);
        let want = reference::bfs_levels(&g, p.source);
        let got = GraphSession::new(&g).run(&p);
        for v in g.vertices() {
            let b = want[v as usize];
            let a = got.values[v as usize];
            if b == u64::MAX {
                assert!(a.is_infinite(), "v{v}");
            } else {
                assert!((a - b as f64).abs() < 1e-12, "v{v}: {a} vs {b}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn weighted_rejects_negative_weights_up_front() {
        let g = crate::graph::GraphBuilder::new(2)
            .weighted_edges(&[(0, 1, -1.0)])
            .build();
        let _ = GraphSession::new(&g).run(&WeightedSssp { source: 0 });
    }

    #[test]
    fn weighted_prefers_cheap_detour_over_direct_hop() {
        // 0 -> 2 costs 10 directly, but 0 -> 1 -> 2 costs 3.
        let g = crate::graph::GraphBuilder::new(3)
            .weighted_edges(&[(0, 2, 10.0), (0, 1, 1.0), (1, 2, 2.0)])
            .build();
        let got = GraphSession::new(&g).run(&WeightedSssp { source: 0 });
        assert_eq!(got.values, vec![0.0, 1.0, 3.0]);
    }
}
