//! Per-vertex triangle counting — the second non-combinable algorithm
//! the log plane unlocks.
//!
//! The classic Pregel-style enumeration over an **undirected, simple**
//! graph (every edge present in both directions, no duplicates). That
//! shape is a *precondition*, not a given: the RMAT / preferential-
//! attachment generators behind the catalog emit parallel edges, and a
//! duplicate edge multiplies announcements and credits. Callers must
//! run on the simple symmetric closure (`GraphBuilder` with
//! `.symmetric(true).dedup(true).drop_self_loops(true)` — the tests do,
//! and the CLI rebuilds the closure before running this program):
//!
//! 1. superstep 0 — every vertex `w` announces itself to each higher-id
//!    neighbour `u > w`;
//! 2. superstep 1 — `u` forwards each announcer `w < u` to each
//!    higher-id neighbour `x > u` as a packed `(w, u)` pair;
//! 3. superstep 2 — `x` checks `w ∈ N(x)` (binary search; CSR rows are
//!    sorted): a hit is the triangle `w < u < x`, counted once at its
//!    highest vertex, which then credits `w` and `u`;
//! 4. superstep 3 — `w` and `u` add their received credits.
//!
//! Each vertex ends with the number of triangles it participates in
//! (`Σ values = 3 × triangle count`). Supersteps 1–3 each need the
//! **full list** of received pairs — candidate pairs cannot be folded
//! into one message by any commutative combine — so the program runs on
//! the [`LogPlane`] and reads its inbox via [`Context::recv`].

use crate::combine::NullCombiner;
use crate::engine::{Context, LogPlane, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// Per-vertex triangle counting. Value = triangles containing the vertex.
#[derive(Clone, Copy, Debug, Default)]
pub struct Triangles;

/// Pack an announcer/forwarder pair into one message word.
#[inline]
pub(crate) fn pack(w: VertexId, u: VertexId) -> u64 {
    ((w as u64) << 32) | u as u64
}

/// Inverse of [`pack`].
#[inline]
pub(crate) fn unpack(p: u64) -> (VertexId, VertexId) {
    ((p >> 32) as VertexId, (p & 0xFFFF_FFFF) as VertexId)
}

impl VertexProgram for Triangles {
    type Value = u64;
    type Message = u64;
    type Comb = NullCombiner;
    type Agg = NoAgg;
    type Delivery = LogPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> NullCombiner {
        NullCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> u64 {
        0
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, _msg: Option<u64>) {
        match ctx.superstep() {
            0 => {
                // Announce to higher-id neighbours.
                let w = ctx.id();
                for i in 0..ctx.out_degree() {
                    let (u, _) = ctx.out_edge(i);
                    if u > w {
                        ctx.send(u, w as u64);
                    }
                }
            }
            1 => {
                // Forward each announcer to higher-id neighbours. Index
                // loops over `recv()` (like the `out_edge` idiom) keep
                // the hot phases allocation-free despite the recv/send
                // borrow alternation.
                let u = ctx.id();
                for mi in 0..ctx.recv().len() {
                    let w = ctx.recv()[mi] as VertexId;
                    for i in 0..ctx.out_degree() {
                        let (x, _) = ctx.out_edge(i);
                        if x > u {
                            ctx.send(x, pack(w, u));
                        }
                    }
                }
            }
            2 => {
                // Close the wedge: w—u—x is a triangle iff w ∈ N(x).
                let mut found = 0u64;
                for mi in 0..ctx.recv().len() {
                    let (w, u) = unpack(ctx.recv()[mi]);
                    if ctx.out_neighbors().binary_search(&w).is_ok() {
                        found += 1;
                        ctx.send(w, 1);
                        ctx.send(u, 1);
                    }
                }
                *ctx.value_mut() += found;
            }
            _ => {
                // Collect credits: one message per triangle this vertex
                // closes at a higher peak.
                *ctx.value_mut() += ctx.recv_iter().count() as u64;
            }
        }
        ctx.vote_to_halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::engine::{EngineConfig, GraphSession};
    use crate::graph::gen;
    use crate::graph::GraphBuilder;

    #[test]
    fn pack_unpack_round_trips() {
        for (w, u) in [(0u32, 0u32), (7, 3), (u32::MAX, 1), (1, u32::MAX)] {
            assert_eq!(unpack(pack(w, u)), (w, u));
        }
    }

    #[test]
    fn single_triangle_counts_once_per_corner() {
        let g = GraphBuilder::new(3)
            .symmetric(true)
            .dedup(true)
            .edges(&[(0, 1), (1, 2), (0, 2)])
            .build();
        let r = GraphSession::new(&g).run(&Triangles);
        assert_eq!(r.values, vec![1, 1, 1]);
    }

    #[test]
    fn triangle_free_graphs_count_zero() {
        // Rings of length ≥ 4 and grids are triangle-free.
        for g in [gen::ring(8), gen::grid(4, 5)] {
            let r = GraphSession::new(&g).run(&Triangles);
            assert!(r.values.iter().all(|&c| c == 0));
        }
    }

    #[test]
    fn complete_graph_counts_choose_two_of_the_rest() {
        // In K6 every vertex sits in C(5,2) = 10 triangles.
        let g = gen::complete(6);
        let r = GraphSession::with_config(&g, EngineConfig::default().threads(3))
            .run(&Triangles);
        assert_eq!(r.values, vec![10; 6]);
        // Quiesces after the fixed 4-phase pipeline.
        assert!(r.metrics.num_supersteps() <= 4);
    }

    #[test]
    fn matches_serial_reference_on_random_symmetric_graphs() {
        for seed in [3u64, 19, 57] {
            let base = gen::rmat(7, 6, 0.57, 0.19, 0.19, seed);
            // Symmetrise + dedup: the program's contract.
            let edges: Vec<(u32, u32)> = base.edges().collect();
            let g = GraphBuilder::new(base.num_vertices())
                .symmetric(true)
                .dedup(true)
                .drop_self_loops(true)
                .edges(&edges)
                .build();
            let r = GraphSession::with_config(&g, EngineConfig::default().threads(4))
                .run(&Triangles);
            assert_eq!(r.values, reference::triangles(&g), "seed {seed}");
            let total: u64 = r.values.iter().sum();
            assert_eq!(total % 3, 0, "each triangle credits exactly 3 corners");
        }
    }
}
