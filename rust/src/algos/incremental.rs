//! Incremental connected components — the paper's §VIII future-work
//! direction ("incrementalisation … could unlock a new level of
//! performance", citing Zakian et al. IPDPS'19), built on the session
//! API's **warm start**.
//!
//! After *edge insertions*, min-labels can only decrease, so the previous
//! fixpoint is a valid warm start: seed every vertex with its old label
//! ([`crate::engine::RunOptions::warm_start`]) and activate only the
//! endpoints of the new edges. The wave then touches just the vertices
//! whose component actually changed, instead of re-converging from
//! scratch. (Deletions can *raise* labels and invalidate the warm start;
//! [`IncrementalCc::supports`] rejects them.)

use crate::combine::MinCombiner;
use crate::engine::{
    Context, EngineConfig, GraphSession, Mode, NoAgg, RunOptions, RunResult, VertexProgram,
};
use crate::graph::csr::{Csr, VertexId};
use crate::graph::GraphBuilder;

/// Warm-started min-label propagation.
///
/// This program **requires** [`RunOptions::warm_start`] with the
/// previous fixpoint's labels: only the `touched` endpoints start
/// active, so a cold start could never propagate labels to the rest of
/// the graph. Running it without warm-start values panics immediately
/// (in `init`) rather than silently returning non-fixpoint labels.
pub struct IncrementalCc {
    /// Endpoints of the inserted edges (the initially active set).
    pub touched: Vec<VertexId>,
}

impl IncrementalCc {
    /// Whether a batch of updates is warm-startable (insert-only).
    pub fn supports(inserts: usize, deletes: usize) -> bool {
        inserts > 0 && deletes == 0
    }
}

impl VertexProgram for IncrementalCc {
    type Value = u32;
    type Message = u32;
    type Comb = MinCombiner;
    type Agg = NoAgg;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> u32 {
        // `init` is only consulted when no warm start was supplied — and a
        // cold IncrementalCc run would silently produce non-fixpoint
        // labels (most vertices never activate). Fail fast instead.
        panic!(
            "IncrementalCc requires RunOptions::warm_start(prior labels); \
             run ConnectedComponents for a cold computation"
        );
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        self.touched.contains(&v)
    }

    fn compute<C: Context<u32, u32>>(&self, ctx: &mut C, msg: Option<u32>) {
        // Superstep 0: the touched endpoints re-announce their labels so
        // the two merged components can see each other. Afterwards:
        // standard min-label propagation.
        if ctx.superstep() == 0 {
            let label = *ctx.value();
            ctx.broadcast(label);
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                ctx.broadcast(m);
            }
        }
        ctx.vote_to_halt();
    }
}

/// Apply insert-only updates to `g` and incrementally repair `labels` by
/// warm-starting from the previous fixpoint. Returns the new graph and
/// the repaired labels plus run metrics.
pub fn insert_edges(
    g: &Csr,
    labels: &[u32],
    inserts: &[(VertexId, VertexId)],
    cfg: EngineConfig,
) -> (Csr, RunResult<u32>) {
    let mut gb = GraphBuilder::new(g.num_vertices()).symmetric(true);
    for (s, d) in g.edges() {
        // Existing edges are already symmetric pairs; keep one direction.
        if s <= d {
            gb.push_edge(s, d);
        }
    }
    for &(s, d) in inserts {
        gb.push_edge(s, d);
    }
    let g2 = gb.build();
    let touched: Vec<VertexId> = inserts.iter().flat_map(|&(s, d)| [s, d]).collect();
    let prog = IncrementalCc { touched };
    let session = GraphSession::with_config(&g2, cfg.bypass(true));
    let result = session.run_with(&prog, RunOptions::new().warm_start(labels));
    (g2, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{reference, ConnectedComponents};
    use crate::util::quick;
    use crate::graph::gen;

    fn cc_bypass(g: &Csr) -> RunResult<u32> {
        GraphSession::with_config(g, EngineConfig::default().bypass(true))
            .run(&ConnectedComponents)
    }

    #[test]
    fn merging_two_rings_updates_only_the_higher_labelled_one() {
        let g = gen::disjoint_rings(2, 30); // components {0..30}, {30..60}
        let base = cc_bypass(&g);
        let (g2, inc) = insert_edges(&g, &base.values, &[(5, 45)], EngineConfig::default());
        // All vertices now share label 0.
        assert!(inc.values.iter().all(|&l| l == 0));
        assert_eq!(inc.values, reference::connected_components(&g2));
        // The warm start touches far fewer vertices than a cold rerun.
        let cold = cc_bypass(&g2);
        assert!(
            inc.metrics.total_activations() < cold.metrics.total_activations(),
            "incremental {} vs cold {}",
            inc.metrics.total_activations(),
            cold.metrics.total_activations()
        );
    }

    #[test]
    fn insert_within_a_component_converges_immediately() {
        let g = gen::ring(50);
        let base = cc_bypass(&g);
        let (g2, inc) = insert_edges(&g, &base.values, &[(3, 30)], EngineConfig::default());
        assert_eq!(inc.values, reference::connected_components(&g2));
        // Labels unchanged → the wave dies after the re-announcement.
        assert!(inc.metrics.num_supersteps() <= 3);
    }

    #[test]
    #[should_panic(expected = "warm_start")]
    fn cold_run_without_warm_start_fails_fast() {
        let g = gen::ring(8);
        let _ = GraphSession::new(&g).run(&IncrementalCc { touched: vec![0] });
    }

    #[test]
    fn supports_rejects_deletions() {
        assert!(IncrementalCc::supports(3, 0));
        assert!(!IncrementalCc::supports(3, 1));
        assert!(!IncrementalCc::supports(0, 0));
    }

    #[test]
    fn prop_incremental_equals_cold_recompute() {
        quick::check("incremental CC == cold CC", |rng| {
            let n = 10 + rng.below(150) as usize;
            let edges = quick::random_edges(rng, n, n);
            let g = GraphBuilder::new(n)
                .symmetric(true)
                .drop_self_loops(true)
                .edges(&edges)
                .build();
            let base = cc_bypass(&g);
            let k = 1 + rng.below(5) as usize;
            let inserts: Vec<(VertexId, VertexId)> = (0..k)
                .map(|_| {
                    (
                        rng.below(n as u64) as VertexId,
                        rng.below(n as u64) as VertexId,
                    )
                })
                .filter(|&(s, d)| s != d)
                .collect();
            if inserts.is_empty() {
                return Ok(());
            }
            let (g2, inc) = insert_edges(&g, &base.values, &inserts, EngineConfig::default());
            let want = reference::connected_components(&g2);
            if inc.values != want {
                return Err(format!("labels differ after {inserts:?}"));
            }
            Ok(())
        });
    }
}
