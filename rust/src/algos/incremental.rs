//! Incremental connected components — the paper's §VIII future-work
//! direction ("incrementalisation … could unlock a new level of
//! performance", citing Zakian et al. IPDPS'19).
//!
//! After *edge insertions*, min-labels can only decrease, so the previous
//! fixpoint is a valid warm start: seed every vertex with its old label
//! and activate only the endpoints of the new edges. The wave then
//! touches just the vertices whose component actually changed, instead of
//! re-converging from scratch. (Deletions can *raise* labels and
//! invalidate the warm start; [`IncrementalCc::supports`] rejects them.)

use crate::combine::MinCombiner;
use crate::engine::{run, Context, EngineConfig, Mode, RunResult, VertexProgram};
use crate::graph::csr::{Csr, VertexId};
use crate::graph::GraphBuilder;

/// Warm-started min-label propagation.
pub struct IncrementalCc {
    /// Converged labels of the pre-update graph.
    pub prior: Vec<u32>,
    /// Endpoints of the inserted edges (the initially active set).
    pub touched: Vec<VertexId>,
}

impl IncrementalCc {
    /// Whether a batch of updates is warm-startable (insert-only).
    pub fn supports(inserts: usize, deletes: usize) -> bool {
        inserts > 0 && deletes == 0
    }
}

impl VertexProgram for IncrementalCc {
    type Value = u32;
    type Message = u32;
    type Comb = MinCombiner;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u32 {
        self.prior[v as usize]
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        self.touched.contains(&v)
    }

    fn compute<C: Context<u32, u32>>(&self, ctx: &mut C, msg: Option<u32>) {
        // Superstep 0: the touched endpoints re-announce their labels so
        // the two merged components can see each other. Afterwards:
        // standard min-label propagation.
        if ctx.superstep() == 0 {
            let label = *ctx.value();
            ctx.broadcast(label);
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                ctx.broadcast(m);
            }
        }
        ctx.vote_to_halt();
    }
}

/// Apply insert-only updates to `g` and incrementally repair `labels`.
/// Returns the new graph, the repaired labels, and the run metrics.
pub fn insert_edges(
    g: &Csr,
    labels: &[u32],
    inserts: &[(VertexId, VertexId)],
    cfg: EngineConfig,
) -> (Csr, RunResult<u32>) {
    let mut gb = GraphBuilder::new(g.num_vertices()).symmetric(true);
    for (s, d) in g.edges() {
        // Existing edges are already symmetric pairs; keep one direction.
        if s <= d {
            gb.push_edge(s, d);
        }
    }
    for &(s, d) in inserts {
        gb.push_edge(s, d);
    }
    let g2 = gb.build();
    let touched: Vec<VertexId> = inserts.iter().flat_map(|&(s, d)| [s, d]).collect();
    let prog = IncrementalCc {
        prior: labels.to_vec(),
        touched,
    };
    let result = run(&g2, &prog, cfg.bypass(true));
    (g2, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{reference, ConnectedComponents};
    use crate::graph::gen;
    use crate::util::quick;

    #[test]
    fn merging_two_rings_updates_only_the_higher_labelled_one() {
        let g = gen::disjoint_rings(2, 30); // components {0..30}, {30..60}
        let base = run(&g, &ConnectedComponents, EngineConfig::default().bypass(true));
        let (g2, inc) = insert_edges(&g, &base.values, &[(5, 45)], EngineConfig::default());
        // All vertices now share label 0.
        assert!(inc.values.iter().all(|&l| l == 0));
        assert_eq!(inc.values, reference::connected_components(&g2));
        // The warm start touches far fewer vertices than a cold rerun.
        let cold = run(&g2, &ConnectedComponents, EngineConfig::default().bypass(true));
        assert!(
            inc.metrics.total_activations() < cold.metrics.total_activations(),
            "incremental {} vs cold {}",
            inc.metrics.total_activations(),
            cold.metrics.total_activations()
        );
    }

    #[test]
    fn insert_within_a_component_converges_immediately() {
        let g = gen::ring(50);
        let base = run(&g, &ConnectedComponents, EngineConfig::default().bypass(true));
        let (g2, inc) = insert_edges(&g, &base.values, &[(3, 30)], EngineConfig::default());
        assert_eq!(inc.values, reference::connected_components(&g2));
        // Labels unchanged → the wave dies after the re-announcement.
        assert!(inc.metrics.num_supersteps() <= 3);
    }

    #[test]
    fn supports_rejects_deletions() {
        assert!(IncrementalCc::supports(3, 0));
        assert!(!IncrementalCc::supports(3, 1));
        assert!(!IncrementalCc::supports(0, 0));
    }

    #[test]
    fn prop_incremental_equals_cold_recompute() {
        quick::check("incremental CC == cold CC", |rng| {
            let n = 10 + rng.below(150) as usize;
            let edges = quick::random_edges(rng, n, n);
            let g = GraphBuilder::new(n)
                .symmetric(true)
                .drop_self_loops(true)
                .edges(&edges)
                .build();
            let base = run(&g, &ConnectedComponents, EngineConfig::default().bypass(true));
            let k = 1 + rng.below(5) as usize;
            let inserts: Vec<(VertexId, VertexId)> = (0..k)
                .map(|_| {
                    (
                        rng.below(n as u64) as VertexId,
                        rng.below(n as u64) as VertexId,
                    )
                })
                .filter(|&(s, d)| s != d)
                .collect();
            if inserts.is_empty() {
                return Ok(());
            }
            let (g2, inc) = insert_edges(&g, &base.values, &inserts, EngineConfig::default());
            let want = reference::connected_components(&g2);
            if inc.values != want {
                return Err(format!("labels differ after {inserts:?}"));
            }
            Ok(())
        });
    }
}
