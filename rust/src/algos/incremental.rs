//! Incremental recomputation — the paper's §VIII future-work direction
//! ("incrementalisation … could unlock a new level of performance",
//! citing Zakian et al. IPDPS'19), built on the session API's **warm
//! start** and, since the dynamic-graph subsystem
//! ([`crate::graph::dynamic`]), on **mutation epochs**.
//!
//! Three delta-driven recomputations live here, all seeding their
//! frontier from the mutated vertices instead of restarting cold:
//!
//! - [`IncrementalCc`] — min-label repair after edge insertions
//!   (insert-only: labels can only decrease, so the old fixpoint is a
//!   valid warm start);
//! - [`IncrementalWsssp`] — weighted shortest-path repair after edge
//!   insertions (insert-only: distances can only decrease);
//! - [`DeltaPageRank`] — tolerance-terminated PageRank that converges
//!   from the previous epoch's ranks in a handful of supersteps
//!   (mutation-agnostic: deletions are fine, the power iteration
//!   re-contracts from wherever it starts).
//!
//! The epoch-validated entry points ([`incremental_cc`],
//! [`incremental_sssp`], [`incremental_pagerank`]) refuse stale inputs:
//! warm-start values must carry the epoch the mutations were applied
//! *from* ([`IncrementalState::epoch`] == [`MutationReceipt::from_epoch`])
//! and the receipt must be the session's *current* epoch — reusing
//! values across unacknowledged mutations is exactly the silent-stale
//! bug this check exists to catch.

use crate::combine::MinCombiner;
use crate::engine::{
    CombinedPlane, Context, EngineConfig, GraphSession, Halt, Mode, NoAgg, RunOptions, RunResult,
    SumAgg, VertexProgram,
};
use crate::graph::csr::{Csr, VertexId};
use crate::graph::dynamic::MutationReceipt;
use crate::graph::GraphBuilder;
use crate::metrics::RunMetrics;
use crate::util::error::Result;
use crate::bail;

/// Warm-start state for the epoch-validated incremental runs: the
/// previous fixpoint's values plus the mutation epoch they reflect.
#[derive(Clone, Debug)]
pub struct IncrementalState<V> {
    /// One value per vertex, from the previous converged run.
    pub values: Vec<V>,
    /// The graph mutation epoch those values were computed at.
    pub epoch: u64,
}

impl<V> IncrementalState<V> {
    /// Bundle `values` computed at `epoch`.
    pub fn new(values: Vec<V>, epoch: u64) -> Self {
        IncrementalState { values, epoch }
    }
}

/// Refuse stale warm starts: `state` must be the fixpoint of the epoch
/// the receipt's mutations were applied from, and the receipt must be
/// the session's current epoch.
fn validate_epochs<V>(
    state: &IncrementalState<V>,
    receipt: &MutationReceipt,
    session: &GraphSession<'_>,
) -> Result<()> {
    if state.epoch != receipt.from_epoch {
        bail!(
            "stale warm start: values are from epoch {} but the mutation batch \
             was applied at epoch {} — recompute or chain the receipts",
            state.epoch,
            receipt.from_epoch
        );
    }
    let current = session.graph_epoch();
    if receipt.epoch != current {
        bail!(
            "stale receipt: batch ended at epoch {} but the session's graph is \
             at epoch {current} — apply receipts in order",
            receipt.epoch
        );
    }
    Ok(())
}

/// The shared gate for the insert-only incremental algorithms (CC,
/// SSSP): epochs must chain, and the batch must not have removed any
/// edge instance — deletions can raise labels/distances, invalidating
/// the monotone warm start.
fn validate_insert_only<V>(
    state: &IncrementalState<V>,
    receipt: &MutationReceipt,
    session: &GraphSession<'_>,
    algo: &str,
) -> Result<()> {
    validate_epochs(state, receipt, session)?;
    if !receipt.removed.is_empty() {
        bail!(
            "incremental {algo} is insert-only (deletions can invalidate the \
             monotone warm start); rerun the cold program for this batch"
        );
    }
    Ok(())
}

/// Warm-started min-label propagation.
///
/// This program **requires** [`RunOptions::warm_start`] with the
/// previous fixpoint's labels: only the `touched` endpoints start
/// active, so a cold start could never propagate labels to the rest of
/// the graph. Running it without warm-start values panics immediately
/// (in `init`) rather than silently returning non-fixpoint labels.
pub struct IncrementalCc {
    /// Endpoints of the inserted edges (the initially active set),
    /// sorted and deduplicated by [`IncrementalCc::new`] — the engine
    /// probes it once per vertex at setup, so membership is a binary
    /// search, not a linear scan.
    touched: Vec<VertexId>,
}

impl IncrementalCc {
    /// Program activating exactly `touched` (the mutation endpoints —
    /// [`MutationReceipt::touched`] ready-made). Sorts and dedups, so
    /// any order is accepted.
    pub fn new(mut touched: Vec<VertexId>) -> Self {
        touched.sort_unstable();
        touched.dedup();
        IncrementalCc { touched }
    }

    /// Whether a batch of updates is warm-startable (insert-only).
    pub fn supports(inserts: usize, deletes: usize) -> bool {
        inserts > 0 && deletes == 0
    }
}

impl VertexProgram for IncrementalCc {
    type Value = u32;
    type Message = u32;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> u32 {
        // `init` is only consulted when no warm start was supplied — and a
        // cold IncrementalCc run would silently produce non-fixpoint
        // labels (most vertices never activate). Fail fast instead.
        panic!(
            "IncrementalCc requires RunOptions::warm_start(prior labels); \
             run ConnectedComponents for a cold computation"
        );
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        self.touched.binary_search(&v).is_ok()
    }

    fn compute<C: Context<u32, u32>>(&self, ctx: &mut C, msg: Option<u32>) {
        // Superstep 0: the touched endpoints re-announce their labels so
        // the two merged components can see each other. Afterwards:
        // standard min-label propagation.
        if ctx.superstep() == 0 {
            let label = *ctx.value();
            ctx.broadcast(label);
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                ctx.broadcast(m);
            }
        }
        ctx.vote_to_halt();
    }
}

/// Warm-started weighted shortest-path repair (push + min-combiner,
/// the same wavefront as [`crate::algos::WeightedSssp`]). Insert-only:
/// new edges can only shorten paths, so the previous distances are a
/// valid warm start and only the `touched` endpoints re-relax.
///
/// Like [`IncrementalCc`], running it without
/// [`RunOptions::warm_start`] panics in `init`.
pub struct IncrementalWsssp {
    /// Endpoints of the inserted edges, sorted and deduplicated by
    /// [`IncrementalWsssp::new`] (binary-searched per vertex at setup).
    touched: Vec<VertexId>,
}

impl IncrementalWsssp {
    /// Program activating exactly `touched`; sorts and dedups, so any
    /// order is accepted.
    pub fn new(mut touched: Vec<VertexId>) -> Self {
        touched.sort_unstable();
        touched.dedup();
        IncrementalWsssp { touched }
    }
}

impl VertexProgram for IncrementalWsssp {
    type Value = f64;
    type Message = f64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, _v: VertexId) -> f64 {
        panic!(
            "IncrementalWsssp requires RunOptions::warm_start(prior distances); \
             run WeightedSssp for a cold computation"
        );
    }

    fn initially_active(&self, _g: &Csr, v: VertexId) -> bool {
        self.touched.binary_search(&v).is_ok()
    }

    fn compute<C: Context<f64, f64>>(&self, ctx: &mut C, msg: Option<f64>) {
        let improved = if ctx.superstep() == 0 {
            // Touched endpoints with a finite distance re-relax every
            // out-edge — the inserted edges among them open the only
            // possible improvements; everything else echoes harmlessly.
            ctx.value().is_finite()
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if improved {
            let dist = *ctx.value();
            for i in 0..ctx.out_degree() {
                let (dst, w) = ctx.out_edge(i);
                ctx.send(dst, dist + w);
            }
        }
        ctx.vote_to_halt();
    }
}

/// Tolerance-terminated PageRank for delta recompute: every superstep
/// aggregates the total absolute rank change (`SumAgg<f64>`), and
/// [`delta_pagerank_halt`] stops the run once it drops to `tol`. From a
/// cold uniform start this is ordinary power iteration; warm-started
/// from the previous epoch's ranks it re-converges in the few
/// supersteps the mutation actually perturbed — deletions included.
#[derive(Clone, Copy, Debug)]
pub struct DeltaPageRank {
    /// Damping factor (0.85, as everywhere in this repo).
    pub damping: f64,
    /// Stop once the superstep's summed |Δrank| is at most this.
    pub tol: f64,
    /// Safety cap on rank-update supersteps.
    pub max_iterations: usize,
}

impl Default for DeltaPageRank {
    fn default() -> Self {
        DeltaPageRank {
            damping: 0.85,
            tol: 1e-10,
            max_iterations: 300,
        }
    }
}

/// The halt policy matching a [`DeltaPageRank`]'s tolerance.
pub fn delta_pagerank_halt(p: &DeltaPageRank) -> Halt<f64> {
    let tol = p.tol;
    Halt::converged(move |_, cur: Option<&f64>| cur.is_some_and(|&d| d <= tol))
}

impl VertexProgram for DeltaPageRank {
    type Value = f64;
    type Message = f64;
    type Comb = crate::combine::SumCombiner;
    type Agg = SumAgg<f64>;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> crate::combine::SumCombiner {
        crate::combine::SumCombiner
    }

    fn aggregator(&self) -> SumAgg<f64> {
        SumAgg::new()
    }

    fn init(&self, g: &Csr, _v: VertexId) -> f64 {
        1.0 / g.num_vertices() as f64
    }

    fn compute<C: Context<f64, f64, f64>>(&self, ctx: &mut C, msg: Option<f64>) {
        let n = ctx.num_vertices() as f64;
        if ctx.superstep() > 0 {
            let sum = msg.unwrap_or(0.0);
            let new = (1.0 - self.damping) / n + self.damping * sum;
            ctx.contribute((new - *ctx.value()).abs());
            *ctx.value_mut() = new;
        }
        if ctx.superstep() < self.max_iterations {
            let deg = ctx.out_degree();
            if deg > 0 {
                let share = *ctx.value() / deg as f64;
                ctx.broadcast(share);
            } else {
                if ctx.superstep() == 0 {
                    // Dangling vertices never broadcast, and an *isolated*
                    // one (no in-edges either) is never reactivated —
                    // pull-mode activation flows along broadcasters'
                    // out-edges — so superstep 0 is its only chance to
                    // settle at the fixpoint (1-d)/n. Deliberately no
                    // contribute(): the superstep-0 aggregator stream
                    // must stay silent or the convergence predicate
                    // could fire before the first real update wave.
                    *ctx.value_mut() = (1.0 - self.damping) / n;
                }
                ctx.vote_to_halt();
            }
        } else {
            ctx.vote_to_halt();
        }
    }
}

/// Epoch-validated incremental CC over a dynamic session: repair
/// `state`'s labels after `receipt`'s insert-only batch by seeding the
/// frontier from the receipt's touched vertices. Returns the run's
/// metrics plus the chained state for the next epoch (the repaired
/// labels live in [`IncrementalState::values`] — moved, not copied, so
/// the per-batch cost stays O(wave), not O(V)).
pub fn incremental_cc(
    session: &GraphSession<'_>,
    state: &IncrementalState<u32>,
    receipt: &MutationReceipt,
) -> Result<(RunMetrics, IncrementalState<u32>)> {
    validate_insert_only(state, receipt, session, "CC")?;
    let prog = IncrementalCc::new(receipt.touched.clone());
    let result = session.run_with(
        &prog,
        RunOptions::new()
            .config(session.config().bypass(true))
            .warm_start(&state.values),
    );
    debug_assert_eq!(result.metrics.graph_epoch, receipt.epoch);
    Ok((
        result.metrics,
        IncrementalState::new(result.values, receipt.epoch),
    ))
}

/// Epoch-validated incremental weighted SSSP over a dynamic session
/// (insert-only, like [`incremental_cc`]). `state` holds the previous
/// distances (`f64::INFINITY` = unreached).
pub fn incremental_sssp(
    session: &GraphSession<'_>,
    state: &IncrementalState<f64>,
    receipt: &MutationReceipt,
) -> Result<(RunMetrics, IncrementalState<f64>)> {
    validate_insert_only(state, receipt, session, "SSSP")?;
    // The cold path rejects negative weights in WeightedSssp::init; the
    // warm path never runs init, so the new edges must be gated here
    // (label-correcting relaxation diverges on negative cycles).
    if let Some(&(s, d, w)) = receipt.inserted.iter().find(|&&(_, _, w)| w < 0.0) {
        bail!(
            "incremental SSSP requires non-negative edge weights; \
             inserted ({s}, {d}) has weight {w}"
        );
    }
    let prog = IncrementalWsssp::new(receipt.touched.clone());
    let result = session.run_with(
        &prog,
        RunOptions::new()
            .config(session.config().bypass(true))
            .warm_start(&state.values),
    );
    debug_assert_eq!(result.metrics.graph_epoch, receipt.epoch);
    Ok((
        result.metrics,
        IncrementalState::new(result.values, receipt.epoch),
    ))
}

/// Epoch-validated incremental PageRank over a dynamic session: warm
/// starts `p` from the previous epoch's ranks and runs to `p.tol`.
/// Tolerates any mutation mix (insertions and deletions).
pub fn incremental_pagerank(
    session: &GraphSession<'_>,
    state: &IncrementalState<f64>,
    receipt: &MutationReceipt,
    p: &DeltaPageRank,
) -> Result<(RunMetrics, IncrementalState<f64>)> {
    validate_epochs(state, receipt, session)?;
    let result = session.run_with(
        p,
        RunOptions::new()
            .halt(delta_pagerank_halt(p))
            .warm_start(&state.values),
    );
    debug_assert_eq!(result.metrics.graph_epoch, receipt.epoch);
    Ok((
        result.metrics,
        IncrementalState::new(result.values, receipt.epoch),
    ))
}

/// Apply insert-only updates to `g` and incrementally repair `labels` by
/// warm-starting from the previous fixpoint. Returns the new graph and
/// the repaired labels plus run metrics.
///
/// This is the pre-dynamic-subsystem path: it **rebuilds** the CSR per
/// batch. Long-lived services should hold a
/// [`GraphSession::dynamic`] session and use [`incremental_cc`], which
/// mutates in place and keeps the session pools warm.
pub fn insert_edges(
    g: &Csr,
    labels: &[u32],
    inserts: &[(VertexId, VertexId)],
    cfg: EngineConfig,
) -> (Csr, RunResult<u32>) {
    let mut gb = GraphBuilder::new(g.num_vertices()).symmetric(true);
    for (s, d) in g.edges() {
        // Existing edges are already symmetric pairs; keep one direction.
        if s <= d {
            gb.push_edge(s, d);
        }
    }
    for &(s, d) in inserts {
        gb.push_edge(s, d);
    }
    let g2 = gb.build();
    let touched: Vec<VertexId> = inserts.iter().flat_map(|&(s, d)| [s, d]).collect();
    let prog = IncrementalCc::new(touched);
    let session = GraphSession::with_config(&g2, cfg.bypass(true));
    let result = session.run_with(&prog, RunOptions::new().warm_start(labels));
    (g2, result)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::{reference, ConnectedComponents, WeightedSssp};
    use crate::graph::dynamic::{DynamicGraph, MutationSet};
    use crate::graph::gen;
    use crate::util::quick;

    fn cc_bypass(g: &Csr) -> RunResult<u32> {
        GraphSession::with_config(g, EngineConfig::default().bypass(true))
            .run(&ConnectedComponents)
    }

    #[test]
    fn merging_two_rings_updates_only_the_higher_labelled_one() {
        let g = gen::disjoint_rings(2, 30); // components {0..30}, {30..60}
        let base = cc_bypass(&g);
        let (g2, inc) = insert_edges(&g, &base.values, &[(5, 45)], EngineConfig::default());
        // All vertices now share label 0.
        assert!(inc.values.iter().all(|&l| l == 0));
        assert_eq!(inc.values, reference::connected_components(&g2));
        // The warm start touches far fewer vertices than a cold rerun.
        let cold = cc_bypass(&g2);
        assert!(
            inc.metrics.total_activations() < cold.metrics.total_activations(),
            "incremental {} vs cold {}",
            inc.metrics.total_activations(),
            cold.metrics.total_activations()
        );
    }

    #[test]
    fn insert_within_a_component_converges_immediately() {
        let g = gen::ring(50);
        let base = cc_bypass(&g);
        let (g2, inc) = insert_edges(&g, &base.values, &[(3, 30)], EngineConfig::default());
        assert_eq!(inc.values, reference::connected_components(&g2));
        // Labels unchanged → the wave dies after the re-announcement.
        assert!(inc.metrics.num_supersteps() <= 3);
    }

    #[test]
    #[should_panic(expected = "warm_start")]
    fn cold_run_without_warm_start_fails_fast() {
        let g = gen::ring(8);
        let _ = GraphSession::new(&g).run(&IncrementalCc::new(vec![0]));
    }

    #[test]
    fn supports_rejects_deletions() {
        assert!(IncrementalCc::supports(3, 0));
        assert!(!IncrementalCc::supports(3, 1));
        assert!(!IncrementalCc::supports(0, 0));
    }

    #[test]
    fn prop_incremental_equals_cold_recompute() {
        quick::check("incremental CC == cold CC", |rng| {
            let n = 10 + rng.below(150) as usize;
            let edges = quick::random_edges(rng, n, n);
            let g = GraphBuilder::new(n)
                .symmetric(true)
                .drop_self_loops(true)
                .edges(&edges)
                .build();
            let base = cc_bypass(&g);
            let k = 1 + rng.below(5) as usize;
            let inserts: Vec<(VertexId, VertexId)> = (0..k)
                .map(|_| {
                    (
                        rng.below(n as u64) as VertexId,
                        rng.below(n as u64) as VertexId,
                    )
                })
                .filter(|&(s, d)| s != d)
                .collect();
            if inserts.is_empty() {
                return Ok(());
            }
            let (g2, inc) = insert_edges(&g, &base.values, &inserts, EngineConfig::default());
            let want = reference::connected_components(&g2);
            if inc.values != want {
                return Err(format!("labels differ after {inserts:?}"));
            }
            Ok(())
        });
    }

    // ---- Epoch-validated dynamic-session paths -----------------------

    fn dynamic_session(g: Csr) -> GraphSession<'static> {
        GraphSession::dynamic_with_config(
            DynamicGraph::with_spill_threshold(g, 1_000_000),
            EngineConfig::default(),
        )
    }

    #[test]
    fn epoch_validated_cc_repairs_across_batches() {
        let g = gen::disjoint_rings(3, 20);
        let mut session = dynamic_session(g);
        let cold = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(session.config().bypass(true)),
        );
        let mut state = IncrementalState::new(cold.values, session.graph_epoch());
        for (a, b) in [(5u32, 25u32), (30, 45)] {
            let mut m = MutationSet::new();
            m.insert_undirected(a, b);
            let receipt = session.apply_mutations(&m).unwrap();
            let (_metrics, next) = incremental_cc(&session, &state, &receipt).unwrap();
            let want = reference::connected_components(session.graph());
            assert_eq!(next.values, want, "after merging {a}-{b}");
            state = next;
        }
        assert_eq!(state.epoch, 2);
    }

    #[test]
    fn epoch_validation_rejects_stale_state_and_receipts() {
        let g = gen::ring(16);
        let mut session = dynamic_session(g);
        let cold = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(session.config().bypass(true)),
        );
        let state = IncrementalState::new(cold.values, session.graph_epoch());
        let mut m = MutationSet::new();
        m.insert_undirected(0, 8);
        let r1 = session.apply_mutations(&m).unwrap();
        // Apply a second batch without consuming r1: r1 is now stale.
        let mut m2 = MutationSet::new();
        m2.insert_undirected(1, 9);
        let r2 = session.apply_mutations(&m2).unwrap();
        let e = incremental_cc(&session, &state, &r1).unwrap_err();
        assert!(e.to_string().contains("stale receipt"), "{e}");
        // And state from epoch 0 does not chain to r2 (from epoch 1).
        let e2 = incremental_cc(&session, &state, &r2).unwrap_err();
        assert!(e2.to_string().contains("stale warm start"), "{e2}");
    }

    #[test]
    fn incremental_cc_rejects_deletions() {
        let g = gen::ring(12);
        let mut session = dynamic_session(g);
        let cold = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(session.config().bypass(true)),
        );
        let state = IncrementalState::new(cold.values, 0);
        let mut m = MutationSet::new();
        m.delete_undirected(0, 1);
        let receipt = session.apply_mutations(&m).unwrap();
        assert!(incremental_cc(&session, &state, &receipt).is_err());
    }

    #[test]
    fn incremental_sssp_matches_cold_on_insert_only_batches() {
        let base = gen::rmat(7, 4, 0.57, 0.19, 0.19, 31);
        let g = gen::randomly_weighted(&base, 0.5, 4.0, 7);
        let source = g.max_out_degree_vertex();
        let mut session = dynamic_session(g);
        let cold = session.run_with(
            &WeightedSssp { source },
            RunOptions::new().config(session.config().bypass(true)),
        );
        let mut state = IncrementalState::new(cold.values, 0);
        let n = session.graph().num_vertices() as u32;
        for round in 0..3u32 {
            let mut m = MutationSet::new();
            m.insert_weighted(round * 3 % n, (round * 17 + 5) % n, 0.25);
            let receipt = session.apply_mutations(&m).unwrap();
            let (_metrics, next) = incremental_sssp(&session, &state, &receipt).unwrap();
            let want = reference::dijkstra(session.graph(), source);
            for v in session.graph().vertices() {
                let (a, b) = (next.values[v as usize], want[v as usize]);
                assert!(
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
                    "round {round} v{v}: {a} vs {b}"
                );
            }
            state = next;
        }
    }

    #[test]
    fn delta_pagerank_warm_start_converges_faster_than_cold() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 51);
        let p = DeltaPageRank::default();
        let mut session = dynamic_session(g);
        let cold = session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
        let mut state = IncrementalState::new(cold.values.clone(), 0);
        let mut m = MutationSet::new();
        m.insert_undirected(0, 200);
        m.delete_undirected(1, 0); // deletions are fine for PageRank
        let receipt = session.apply_mutations(&m).unwrap();
        let (warm, next) = incremental_pagerank(&session, &state, &receipt, &p).unwrap();
        assert!(
            warm.num_supersteps() < cold.metrics.num_supersteps(),
            "warm {} vs cold {}",
            warm.num_supersteps(),
            cold.metrics.num_supersteps()
        );
        // Warm fixpoint agrees with a cold fixpoint on the mutated graph.
        let cold2 = session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
        for v in session.graph().vertices() {
            let (a, b) = (next.values[v as usize], cold2.values[v as usize]);
            assert!((a - b).abs() < 1e-7, "v{v}: {a} vs {b}");
        }
        state = next;
        assert_eq!(state.epoch, 1);
    }
}
