//! PageRank — the paper's PR benchmark.
//!
//! Implemented iPregel-style as a *single-broadcast* (pull) program: each
//! vertex broadcasts `rank / out_degree` into its own outbox and the sum
//! of in-neighbour contributions arrives as the combined message. A fixed
//! iteration count (the paper uses 10) bounds the run.

use crate::combine::SumCombiner;
use crate::engine::{CombinedPlane, Context, Mode, NoAgg, VertexProgram};
use crate::graph::csr::{Csr, VertexId};

/// PageRank program. Value = current rank.
#[derive(Clone, Debug)]
pub struct PageRank {
    /// Number of rank-update iterations (supersteps beyond the initial
    /// broadcast). The paper's Table II uses 10.
    pub iterations: usize,
    /// Damping factor (0.85 in the original paper).
    pub damping: f64,
}

impl Default for PageRank {
    fn default() -> Self {
        PageRank {
            iterations: 10,
            damping: 0.85,
        }
    }
}

impl VertexProgram for PageRank {
    type Value = f64;
    type Message = f64;
    type Comb = SumCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> SumCombiner {
        SumCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, g: &Csr, _v: VertexId) -> f64 {
        1.0 / g.num_vertices() as f64
    }

    fn compute<C: Context<f64, f64>>(&self, ctx: &mut C, msg: Option<f64>) {
        let n = ctx.num_vertices() as f64;
        if ctx.superstep() > 0 {
            // Combined sum of in-neighbour contributions. Dangling mass is
            // dropped (the common vertex-centric simplification; the
            // serial reference mirrors it exactly).
            let sum = msg.unwrap_or(0.0);
            *ctx.value_mut() = (1.0 - self.damping) / n + self.damping * sum;
        }
        if ctx.superstep() < self.iterations {
            let deg = ctx.out_degree();
            if deg > 0 {
                let share = *ctx.value() / deg as f64;
                ctx.broadcast(share);
            }
        } else {
            ctx.vote_to_halt();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::reference;
    use crate::engine::{EngineConfig, GraphSession};
    use crate::graph::gen;

    #[test]
    fn matches_serial_reference_on_small_graph() {
        let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 5);
        let pr = PageRank::default();
        let got = GraphSession::with_config(&g, EngineConfig::default().threads(3)).run(&pr);
        let want = reference::pagerank(&g, pr.iterations, pr.damping);
        assert_eq!(got.metrics.num_supersteps(), pr.iterations + 1);
        for v in g.vertices() {
            let (a, b) = (got.values[v as usize], want[v as usize]);
            assert!((a - b).abs() < 1e-12, "v{v}: {a} vs {b}");
        }
    }

    #[test]
    fn rank_mass_bounded_by_one() {
        let g = gen::barabasi_albert(200, 2, 8);
        let got = GraphSession::new(&g).run(&PageRank::default());
        let total: f64 = got.values.iter().sum();
        assert!(total <= 1.0 + 1e-9, "total={total}");
        assert!(total > 0.1);
        assert!(got.values.iter().all(|&r| r > 0.0));
    }

    #[test]
    fn hub_outranks_leaves_on_star() {
        // All leaves point at the hub and vice versa (undirected star).
        let g = gen::star(50);
        let got = GraphSession::new(&g).run(&PageRank::default());
        let hub = got.values[0];
        for v in 1..50 {
            assert!(hub > got.values[v], "hub {hub} vs leaf {}", got.values[v]);
        }
    }

    #[test]
    fn zero_iterations_keeps_uniform_ranks() {
        let g = gen::ring(10);
        let got = GraphSession::new(&g).run(&PageRank {
            iterations: 0,
            damping: 0.85,
        });
        for &r in &got.values {
            assert!((r - 0.1).abs() < 1e-15);
        }
    }
}
