//! Work-stealing shard queues for the partitioned scatter loop.
//!
//! [`parallel_for_hinted`] hands each worker a fixed chunk list (or an
//! FCFS cursor); under partitioned execution the dispatch unit is a
//! *shard*, and shard weights are only estimates — a worker whose shards
//! finish early idles at the flush barrier while a peer grinds through a
//! heavy tail. [`steal_execute`] replaces that dispatch with per-worker
//! deques of shard indices: each worker drains its own queue from the
//! bottom, and a drained worker *steals* single items from the top of the
//! most-loaded peer's queue instead of idling (DESIGN.md §2.9).
//!
//! ## Protocol (Chase–Lev, specialised to index ranges)
//!
//! The classic Chase–Lev deque stores items in a growable ring buffer.
//! Here the item *is* its index: worker `w` owns the contiguous range
//! `cuts[w]..cuts[w+1]` of shard ids, so the queue needs no buffer at
//! all — just the two cursors:
//!
//! ```text
//! start ≤ top ≤ bottom           (queue holds top..bottom)
//! owner  pops  at bottom (LIFO side, uncontended fast path)
//! thieves CAS  at top    (FIFO side, one item per CAS)
//! ```
//!
//! Because the "buffer" is the immutable index range itself, the classic
//! read-after-reuse hazard (a thief reading a slot the owner already
//! overwrote) cannot occur: a successful CAS on `top` *is* ownership of
//! index `t`, full stop. The orderings are the textbook ones and are
//! sanctioned in `audit/orderings.toml`:
//!
//! - owner pop: `bottom` store Relaxed, then `fence(SeqCst)`, then `top`
//!   load Relaxed — the fence makes the pop visible to any thief whose
//!   own fence follows, so owner and thief can never both claim the last
//!   item without one of them seeing the other's cursor;
//! - last-item tie: both sides race a SeqCst CAS on `top`; exactly one
//!   wins;
//! - thief: Acquire loads of both cursors around a `fence(SeqCst)`, then
//!   the SeqCst CAS.
//!
//! Multi-item steals (CAS `top` forward by k) were considered and
//! rejected: the owner only defends the single `bottom` item in the
//! tie-break CAS, so a k-item claim could overlap items the owner pops
//! concurrently — double execution. Instead, steal *granularity* is a
//! loop of single-item CASes per steal episode
//! ([`steal_execute`]'s `steal_chunk`), which amortises the victim scan
//! without weakening the protocol.
//!
//! Under `--features race-check` every item carries a [`ShadowCell`];
//! executing it records a same-phase unsynchronised write, so an item
//! executed twice in one phase — the only way this protocol can fail —
//! panics deterministically (see `tests/test_race.rs`).

use crate::util::prefix::{balanced_cuts, exclusive_prefix_sum};
use crate::util::CachePadded;
use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};

#[cfg(feature = "race-check")]
use crate::util::shadow::{PhaseGuard, ShadowCell, Site};

/// One worker's deque over its contiguous index range. The range never
/// grows, so `start` is immutable and only the two cursors are shared.
struct StealQueue {
    /// Lower bound of this worker's range; `top` never moves below it.
    start: usize,
    /// Steal side: first unclaimed index. Monotonically non-decreasing.
    top: AtomicUsize,
    /// Owner side: one past the last unclaimed index.
    bottom: AtomicUsize,
}

/// A set of per-worker stealing deques partitioning `0..n`.
///
/// Construction seeds worker `w` with `cuts[w]..cuts[w+1]`, where the
/// cuts come from [`balanced_cuts`] over the item weights (equal item
/// counts when no weights are given) — the same cut the fixed dispatch
/// would use, so with zero steals the assignment is identical.
pub struct StealSet {
    queues: Vec<CachePadded<StealQueue>>,
    /// Per-worker successful-steal counters (Relaxed: statistics only).
    steals: Vec<CachePadded<AtomicU64>>,
    /// One shadow cell per item: execution is an unsynchronised write,
    /// so a double-executed item trips the race checker.
    #[cfg(feature = "race-check")]
    shadows: Vec<ShadowCell>,
}

impl StealSet {
    /// Partition `0..n` across `workers` deques, weighted by `weights`
    /// when given (item → work units, e.g. active edge counts per shard).
    pub fn new(n: usize, workers: usize, weights: Option<&[u64]>) -> StealSet {
        let workers = workers.max(1);
        let cuts = match weights {
            Some(w) => {
                debug_assert_eq!(w.len(), n);
                balanced_cuts(&exclusive_prefix_sum(w), workers)
            }
            None => (0..=workers).map(|t| n * t / workers).collect(),
        };
        let queues = (0..workers)
            .map(|w| {
                CachePadded::new(StealQueue {
                    start: cuts[w],
                    top: AtomicUsize::new(cuts[w]),
                    bottom: AtomicUsize::new(cuts[w + 1]),
                })
            })
            .collect();
        StealSet {
            queues,
            steals: (0..workers)
                .map(|_| CachePadded::new(AtomicU64::new(0)))
                .collect(),
            #[cfg(feature = "race-check")]
            shadows: (0..n).map(|_| ShadowCell::new()).collect(),
        }
    }

    /// Number of worker deques.
    pub fn workers(&self) -> usize {
        self.queues.len()
    }

    /// Owner pop from the bottom of worker `w`'s own deque.
    pub fn take(&self, w: usize) -> Option<usize> {
        let q = &self.queues[w];
        let b = q.bottom.load(Ordering::Relaxed);
        if b == q.start {
            return None; // empty, and thieves cannot make it emptier
        }
        let b = b - 1;
        // Publish the claim of index b, then look at the steal cursor.
        // The SeqCst fence pairs with the thief's fence: whichever side's
        // fence is later sees the other's cursor update, so both claiming
        // item b unobserved is impossible.
        q.bottom.store(b, Ordering::Relaxed);
        fence(Ordering::SeqCst);
        let t = q.top.load(Ordering::Relaxed);
        if t < b {
            // More than one item remained: b is uncontended.
            return Some(b);
        }
        // Restore bottom either way: the queue is empty after this pop
        // attempt, and top must stay ≤ bottom for thieves' range checks.
        q.bottom.store(b + 1, Ordering::Relaxed);
        if t == b {
            // Last item: race any thief for it via the top cursor.
            if q.top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok()
            {
                return Some(b);
            }
        }
        None
    }

    /// Thief-side single-item claim from the top of `victim`'s deque.
    pub fn steal_from(&self, thief: usize, victim: usize) -> Option<usize> {
        let q = &self.queues[victim];
        let t = q.top.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = q.bottom.load(Ordering::Acquire);
        if t >= b {
            return None; // empty (or the owner is mid-pop on the last item)
        }
        if q.top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
            .is_ok()
        {
            self.steals[thief].fetch_add(1, Ordering::Relaxed);
            return Some(t);
        }
        None
    }

    /// The peer of `w` with the most unclaimed items, or `None` when all
    /// peers look empty. A load-time estimate — the answer can be stale
    /// by the time the steal lands, which only costs a failed CAS.
    pub fn most_loaded(&self, w: usize) -> Option<usize> {
        let mut best = None;
        let mut best_len = 0usize;
        for (v, q) in self.queues.iter().enumerate() {
            if v == w {
                continue;
            }
            let len = q
                .bottom
                .load(Ordering::Relaxed)
                .saturating_sub(q.top.load(Ordering::Relaxed));
            if len > best_len {
                best_len = len;
                best = Some(v);
            }
        }
        best
    }

    /// Record that item `i` is about to execute. Under `race-check` this
    /// is an unsynchronised write to the item's shadow cell: exactly one
    /// execution per phase is legal, so a protocol violation (double
    /// claim) panics with both sites.
    #[inline]
    #[allow(unused_variables)]
    pub fn mark_execute(&self, i: usize) {
        #[cfg(feature = "race-check")]
        self.shadows[i].on_write(Site::StealItem, false);
    }

    /// Total successful steals across all workers.
    pub fn steals_total(&self) -> u64 {
        self.steals
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }
}

/// Below this many items the thread-spawn cost dominates: run serially
/// (mirrors `sched::pool`'s cutoff so the two dispatchers agree).
const SERIAL_CUTOFF: usize = 4096;

/// Execute `body(worker, item)` for every item in `0..n` on `threads`
/// workers with work stealing, returning the number of successful steals.
///
/// Seeding matches the fixed dispatch: worker `w` starts with the
/// weight-balanced range `cuts[w]..cuts[w+1]` and drains it bottom-up
/// (i.e. in *descending* index order — order within a worker is
/// unspecified, exactly as under FCFS schedules). A drained worker runs
/// steal episodes: up to `steal_chunk` single-item steals from the
/// currently most-loaded peer, executing each immediately, and exits
/// when an episode yields nothing.
///
/// `work_hint` gates the serial cutoff (pass the number of *active*
/// items so near-empty supersteps skip the spawns, like
/// `parallel_for_hinted`).
pub fn steal_execute<F>(
    threads: usize,
    n: usize,
    weights: Option<&[u64]>,
    steal_chunk: usize,
    work_hint: usize,
    body: F,
) -> u64
where
    F: Fn(usize, usize) + Sync,
{
    steal_execute_tagged(threads, n, weights, steal_chunk, work_hint, move |w, i, _| {
        body(w, i)
    })
}

/// [`steal_execute`] with provenance: `body(worker, item, stolen)`
/// receives `stolen = true` exactly when the item was claimed from a
/// peer's deque, so callers (the observability plane) can attribute
/// migrated work without a second counting pass. The `stolen = true`
/// call count equals the returned steal total.
pub fn steal_execute_tagged<F>(
    threads: usize,
    n: usize,
    weights: Option<&[u64]>,
    steal_chunk: usize,
    work_hint: usize,
    body: F,
) -> u64
where
    F: Fn(usize, usize, bool) + Sync,
{
    let threads = threads.max(1);
    if n == 0 {
        return 0;
    }
    #[cfg(feature = "race-check")]
    let _phase = PhaseGuard::enter();
    if threads == 1 || work_hint < SERIAL_CUTOFF {
        for i in 0..n {
            body(0, i, false);
        }
        return 0;
    }
    let set = StealSet::new(n, threads, weights);
    let chunk = steal_chunk.max(1);
    let set_ref = &set;
    let body_ref = &body;
    std::thread::scope(|scope| {
        for w in 0..threads {
            scope.spawn(move || {
                loop {
                    // Drain own deque first: uncontended fast path.
                    while let Some(i) = set_ref.take(w) {
                        set_ref.mark_execute(i);
                        body_ref(w, i, false);
                    }
                    // Steal episode: up to `chunk` items from the most
                    // loaded peer, re-picking the victim per item so a
                    // raced-away queue redirects the episode.
                    let mut stole = false;
                    for _ in 0..chunk {
                        let Some(v) = set_ref.most_loaded(w) else { break };
                        if let Some(i) = set_ref.steal_from(w, v) {
                            set_ref.mark_execute(i);
                            body_ref(w, i, true);
                            stole = true;
                        } else if !stole {
                            // Lost the race and have stolen nothing yet:
                            // retry the scan rather than giving up on a
                            // single failed CAS.
                            if set_ref.most_loaded(w).is_none() {
                                break;
                            }
                        }
                    }
                    if !stole {
                        // Own queue empty and nothing stealable: even if
                        // a peer still *executes* items, none are
                        // unclaimed — the region is drained for us.
                        break;
                    }
                }
            });
        }
    });
    set.steals_total()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn serial_owner_drains_in_descending_order() {
        let set = StealSet::new(5, 1, None);
        let mut got = Vec::new();
        while let Some(i) = set.take(0) {
            got.push(i);
        }
        assert_eq!(got, vec![4, 3, 2, 1, 0]);
        assert_eq!(set.take(0), None);
        assert_eq!(set.steals_total(), 0);
    }

    #[test]
    fn seeding_matches_balanced_cuts() {
        // Weights concentrate on item 3: cuts should isolate it.
        let w = [1u64, 1, 1, 97];
        let set = StealSet::new(4, 2, Some(&w));
        // Worker 0 gets 0..3, worker 1 gets 3..4 (97% of the weight).
        let mut own0 = Vec::new();
        while let Some(i) = set.take(0) {
            own0.push(i);
        }
        assert_eq!(own0, vec![2, 1, 0]);
        assert_eq!(set.take(1), Some(3));
        assert_eq!(set.take(1), None);
    }

    #[test]
    fn thief_takes_from_the_top() {
        let set = StealSet::new(4, 2, None); // w0: 0..2, w1: 2..4
        assert_eq!(set.steal_from(1, 0), Some(0));
        assert_eq!(set.steal_from(1, 0), Some(1));
        assert_eq!(set.steal_from(1, 0), None);
        assert_eq!(set.steals_total(), 2);
        // Owner still owns its (now empty) queue.
        assert_eq!(set.take(0), None);
    }

    #[test]
    fn most_loaded_picks_the_longest_peer_queue() {
        let w = [1u64, 1, 1, 1, 1, 1, 1, 1]; // equal → cuts 0..4, 4..8
        let set = StealSet::new(8, 2, Some(&w));
        assert_eq!(set.most_loaded(0), Some(1));
        set.take(1);
        set.take(1);
        set.take(1);
        set.take(1);
        assert_eq!(set.most_loaded(0), None, "peer drained");
        assert_eq!(set.most_loaded(1), Some(0));
    }

    #[test]
    fn every_item_executes_exactly_once_under_contention() {
        // 2 workers, all weight in worker 0's range: worker 1 must steal.
        let n = 8192usize;
        let mut w = vec![0u64; n];
        for x in w.iter_mut().take(n / 8) {
            *x = 1000;
        }
        let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let steals = steal_execute(4, n, Some(&w), 2, n, |_t, i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, c) in counts.iter().enumerate() {
            assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} execution count");
        }
        // Three workers start (almost) empty; they must have stolen.
        assert!(steals > 0, "expected at least one steal");
    }

    #[test]
    fn serial_cutoff_runs_in_order_with_zero_steals() {
        let order = std::sync::Mutex::new(Vec::new());
        let steals = steal_execute(8, 64, None, 4, 64, |t, i| {
            assert_eq!(t, 0);
            order.lock().unwrap().push(i);
        });
        assert_eq!(steals, 0);
        assert_eq!(*order.lock().unwrap(), (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn empty_range_is_a_no_op() {
        assert_eq!(steal_execute(4, 0, None, 1, 0, |_, _| panic!("no items")), 0);
    }

    #[test]
    fn more_workers_than_items_leaves_tail_queues_empty() {
        let counts: Vec<AtomicU64> = (0..3).map(|_| AtomicU64::new(0)).collect();
        // work_hint ≥ cutoff forces the parallel path even for 3 items.
        let _ = steal_execute(8, 3, None, 1, SERIAL_CUTOFF, |_t, i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
        });
        for c in &counts {
            assert_eq!(c.load(Ordering::Relaxed), 1);
        }
    }
}
