//! Real-thread execution of a schedule.
//!
//! [`parallel_for`] runs `body(thread_id, item_range)` over `0..n` with
//! the chunk-claiming semantics of the given [`Schedule`]. Scoped threads
//! are spawned per call: supersteps are millisecond-scale regions, so the
//! tens-of-microseconds spawn cost is noise, and scoping lets bodies
//! borrow engine state without `Arc` gymnastics (the virtual testbed, not
//! real threading, is the performance-measurement path on this 1-core
//! machine — see DESIGN.md §3).

use crate::sched::Schedule;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Execute `body(tid, range)` over the chunk decomposition of `0..n`.
///
/// - Pre-partitioned schedules (static, edge-centric): chunk `t` runs on
///   thread `t`.
/// - FCFS schedules (dynamic, guided): threads claim chunks from a shared
///   atomic cursor, first-come-first-served — OpenMP semantics.
///
/// `weights` is required for [`Schedule::EdgeCentric`].
pub fn parallel_for<F>(
    threads: usize,
    n: usize,
    sched: Schedule,
    weights: Option<&[u64]>,
    body: F,
) where
    F: Fn(usize, Range<usize>) + Sync,
{
    parallel_for_hinted(threads, n, sched, weights, n, body)
}

/// [`parallel_for`] with the serial cutoff judged against `work_hint`
/// instead of the item count. The partitioned engine dispatches *shards*
/// (a handful of items, each carrying thousands of vertices), so the
/// item count says nothing about whether spawning a team pays off —
/// the caller passes the active-vertex total instead.
pub fn parallel_for_hinted<F>(
    threads: usize,
    n: usize,
    sched: Schedule,
    weights: Option<&[u64]>,
    work_hint: usize,
    body: F,
) where
    F: Fn(usize, Range<usize>) + Sync,
{
    let threads = threads.max(1);
    if n == 0 {
        return;
    }
    // Race-check epoch bracket: every parallel region (including the
    // inline serial path — uniformity keeps the epoch algebra trivial)
    // gets a fresh phase on entry, and the serial code after the scope
    // join gets one on drop. See `util::shadow`.
    #[cfg(feature = "race-check")]
    let _phase = crate::util::shadow::PhaseGuard::enter();
    let chunks = sched.chunks(n, threads, weights);
    // Adaptive serial cutoff (§Perf L3): spawning + joining the team
    // costs ~75 µs on this host, which dwarfs the work when the active
    // set is tiny (deep-diameter graphs spend *every* superstep there —
    // a 600×600 grid SSSP has 1 200 supersteps of ≤1 198-vertex
    // frontiers). Below the cutoff the caller runs the chunks inline.
    const SERIAL_CUTOFF: usize = 4096;
    if threads == 1 || work_hint < SERIAL_CUTOFF {
        for r in chunks {
            body(0, r);
        }
        return;
    }
    if sched.is_fcfs() {
        let cursor = AtomicUsize::new(0);
        let chunks = &chunks;
        let body = &body;
        let cursor = &cursor;
        std::thread::scope(|s| {
            for tid in 0..threads {
                s.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    match chunks.get(i) {
                        Some(r) => body(tid, r.clone()),
                        None => break,
                    }
                });
            }
        });
    } else {
        let chunks = &chunks;
        let body = &body;
        std::thread::scope(|s| {
            for (tid, r) in chunks.iter().enumerate() {
                if r.is_empty() {
                    continue;
                }
                let r = r.clone();
                s.spawn(move || body(tid, r));
            }
        });
    }
}

/// Convenience: per-item body instead of per-range.
pub fn parallel_for_each<F>(
    threads: usize,
    n: usize,
    sched: Schedule,
    weights: Option<&[u64]>,
    body: F,
) where
    F: Fn(usize, usize) + Sync,
{
    parallel_for(threads, n, sched, weights, |tid, range| {
        for i in range {
            body(tid, i);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

    fn run_and_count(threads: usize, n: usize, sched: Schedule, weights: Option<&[u64]>) {
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        parallel_for_each(threads, n, sched, weights, |_tid, i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} under {sched:?}");
        }
    }

    #[test]
    fn every_schedule_visits_each_item_once_with_real_threads() {
        let weights: Vec<u64> = (0..1000).map(|i| (i % 13) + 1).collect();
        for threads in [1, 2, 4, 8] {
            run_and_count(threads, 1000, Schedule::Static, None);
            run_and_count(threads, 1000, Schedule::Dynamic { chunk: 7 }, None);
            run_and_count(threads, 1000, Schedule::Guided { min_chunk: 3 }, None);
            run_and_count(threads, 1000, Schedule::EdgeCentric, Some(&weights));
        }
    }

    #[test]
    fn hinted_variant_visits_each_item_once_even_when_parallel() {
        // 8 items with a large work hint: the cutoff is bypassed, so the
        // chunks run on real threads — shard-dispatch shape.
        for sched in [Schedule::Static, Schedule::Dynamic { chunk: 1 }] {
            let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
            parallel_for_hinted(4, 8, sched, None, 1_000_000, |_tid, range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "item {i} under {sched:?}");
            }
        }
    }

    #[test]
    fn zero_items_is_a_noop() {
        parallel_for_each(4, 0, Schedule::Static, None, |_, _| {
            panic!("must not be called")
        });
    }

    #[test]
    fn sum_reduction_is_correct_under_contention() {
        let total = AtomicU64::new(0);
        parallel_for_each(8, 10_000, Schedule::Dynamic { chunk: 16 }, None, |_, i| {
            total.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 10_000 * 9_999 / 2);
    }

    #[test]
    fn tids_stay_in_range() {
        let n = 500;
        let max_tid = AtomicUsize::new(0);
        parallel_for(4, n, Schedule::Dynamic { chunk: 8 }, None, |tid, _| {
            max_tid.fetch_max(tid, Ordering::Relaxed);
        });
        assert!(max_tid.load(Ordering::Relaxed) < 4);
    }
}
