//! Work distribution across threads (§V).
//!
//! A [`Schedule`] describes how the iteration space of one superstep is
//! cut into chunks and handed to workers:
//!
//! - [`Schedule::Static`] — equal *item-count* contiguous ranges, the
//!   common vertex-centric default and the paper's baseline;
//! - [`Schedule::Dynamic`] — OpenMP `schedule(dynamic, chunk)` semantics:
//!   fixed-size chunks claimed first-come-first-served from an atomic
//!   counter (§V-B; the paper's empirically-best chunk is 256);
//! - [`Schedule::Guided`] — OpenMP guided: exponentially shrinking chunks;
//! - [`Schedule::EdgeCentric`] — the paper's §V-A contribution: ranges cut
//!   so each worker receives an equal number of *edges* (degree-weighted
//!   prefix sums), while the user-visible model stays vertex-centric.
//!
//! [`parallel_for`] executes a body over `0..n` under any schedule using
//! real threads; [`Schedule::chunks`] exposes the same decomposition to
//! the virtual testbed ([`crate::sim`]) so simulated runs use *exactly*
//! the distribution semantics of real runs.

pub mod pool;
pub mod steal;

use crate::util::prefix::{balanced_cuts, exclusive_prefix_sum};
use std::ops::Range;

pub use pool::{parallel_for, parallel_for_hinted};
pub use steal::{steal_execute, steal_execute_tagged, StealSet};

/// Default dynamic chunk size — the paper's empirically determined 256.
pub const DEFAULT_CHUNK: usize = 256;

/// A work-distribution policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Schedule {
    /// Equal item counts per thread (baseline).
    Static,
    /// FCFS fixed-size chunks (OpenMP dynamic).
    Dynamic { chunk: usize },
    /// FCFS exponentially shrinking chunks (OpenMP guided).
    Guided { min_chunk: usize },
    /// Equal *edge* counts per thread (paper §V-A). Incompatible with
    /// dynamic chunking: the ranges are precomputed per superstep from
    /// the active vertices' degrees (which is also why the paper pits it
    /// *against* dynamic scheduling rather than composing them).
    ///
    /// **With selection bypass** the iteration space changes every
    /// superstep, so the precomputed-weights premise does not hold: the
    /// engine falls back to rebuilding the degree-weight vector from
    /// the active list each superstep. This fallback is documented
    /// behaviour, warned once per process on stderr, and surfaced in
    /// [`RunMetrics::schedule_fallback`].
    ///
    /// **Under partitioned execution** the edge-centric cut is applied
    /// *per shard*: the dispatch unit becomes the shard, weighted by its
    /// (active) edge count — the natural home for this schedule, since
    /// the shard boundaries themselves come from the same
    /// degree-balanced cut ([`crate::graph::partition::PartitionPlan`]).
    ///
    /// [`RunMetrics::schedule_fallback`]: crate::metrics::RunMetrics::schedule_fallback
    EdgeCentric,
}

impl Schedule {
    /// The granularity this policy uses when the dispatch unit is a
    /// *shard* rather than a vertex: FCFS policies claim one shard at a
    /// time (a fixed chunk of hundreds of vertices would collapse a
    /// handful of shards into a single claim), the pre-partitioned
    /// policies are unchanged.
    pub fn for_shards(self) -> Schedule {
        match self {
            Schedule::Dynamic { .. } => Schedule::Dynamic { chunk: 1 },
            Schedule::Guided { .. } => Schedule::Guided { min_chunk: 1 },
            s => s,
        }
    }
}

impl Schedule {
    /// Parse from CLI text: `static`, `dynamic[:chunk]`, `guided[:min]`,
    /// `edge-centric`.
    pub fn parse(s: &str) -> Option<Schedule> {
        let (kind, param) = match s.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (s, None),
        };
        match kind {
            "static" => Some(Schedule::Static),
            "dynamic" => Some(Schedule::Dynamic {
                chunk: param.and_then(|p| p.parse().ok()).unwrap_or(DEFAULT_CHUNK),
            }),
            "guided" => Some(Schedule::Guided {
                min_chunk: param.and_then(|p| p.parse().ok()).unwrap_or(1),
            }),
            "edge-centric" | "edge" => Some(Schedule::EdgeCentric),
            _ => None,
        }
    }

    /// Whether this schedule needs per-item weights (degrees).
    pub fn needs_weights(self) -> bool {
        matches!(self, Schedule::EdgeCentric)
    }

    /// Decompose `0..n` into the ordered chunk list this policy would
    /// produce for `threads` workers. For FCFS policies the chunks are
    /// claimed in this order; for pre-partitioned policies chunk `t`
    /// belongs to thread `t`.
    ///
    /// `weights` (item → work units, e.g. degrees) is required for
    /// [`Schedule::EdgeCentric`] and ignored otherwise.
    pub fn chunks(self, n: usize, threads: usize, weights: Option<&[u64]>) -> Vec<Range<usize>> {
        let threads = threads.max(1);
        match self {
            Schedule::Static => {
                let mut out = Vec::with_capacity(threads);
                for t in 0..threads {
                    let lo = n * t / threads;
                    let hi = n * (t + 1) / threads;
                    out.push(lo..hi);
                }
                out
            }
            Schedule::Dynamic { chunk } => {
                let chunk = chunk.max(1);
                let mut out = Vec::with_capacity(crate::util::div_ceil(n.max(1), chunk));
                let mut lo = 0;
                while lo < n {
                    let hi = (lo + chunk).min(n);
                    out.push(lo..hi);
                    lo = hi;
                }
                out
            }
            Schedule::Guided { min_chunk } => {
                let min_chunk = min_chunk.max(1);
                let mut out = Vec::new();
                let mut lo = 0;
                while lo < n {
                    let remaining = n - lo;
                    let c = (remaining / threads).max(min_chunk).min(remaining);
                    out.push(lo..lo + c);
                    lo += c;
                }
                out
            }
            Schedule::EdgeCentric => {
                let w = weights.expect("EdgeCentric schedule requires per-item weights");
                assert_eq!(w.len(), n, "weights length must match item count");
                let prefix = exclusive_prefix_sum(w);
                let cuts = balanced_cuts(&prefix, threads);
                cuts.windows(2).map(|c| c[0]..c[1]).collect()
            }
        }
    }

    /// True when chunks are claimed FCFS at runtime (load-adaptive) rather
    /// than pre-assigned to threads.
    pub fn is_fcfs(self) -> bool {
        matches!(self, Schedule::Dynamic { .. } | Schedule::Guided { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quick;

    fn covers_exactly(chunks: &[Range<usize>], n: usize) -> Result<(), String> {
        let mut seen = vec![false; n];
        for r in chunks {
            for i in r.clone() {
                if seen[i] {
                    return Err(format!("item {i} covered twice"));
                }
                seen[i] = true;
            }
        }
        match seen.iter().position(|&s| !s) {
            Some(i) => Err(format!("item {i} not covered")),
            None => Ok(()),
        }
    }

    #[test]
    fn parse_all_kinds() {
        assert_eq!(Schedule::parse("static"), Some(Schedule::Static));
        assert_eq!(
            Schedule::parse("dynamic"),
            Some(Schedule::Dynamic { chunk: 256 })
        );
        assert_eq!(
            Schedule::parse("dynamic:64"),
            Some(Schedule::Dynamic { chunk: 64 })
        );
        assert_eq!(
            Schedule::parse("guided:8"),
            Some(Schedule::Guided { min_chunk: 8 })
        );
        assert_eq!(Schedule::parse("edge-centric"), Some(Schedule::EdgeCentric));
        assert_eq!(Schedule::parse("bogus"), None);
    }

    #[test]
    fn shard_granularity_claims_one_at_a_time() {
        assert_eq!(
            Schedule::Dynamic { chunk: 256 }.for_shards(),
            Schedule::Dynamic { chunk: 1 }
        );
        assert_eq!(
            Schedule::Guided { min_chunk: 8 }.for_shards(),
            Schedule::Guided { min_chunk: 1 }
        );
        assert_eq!(Schedule::Static.for_shards(), Schedule::Static);
        assert_eq!(Schedule::EdgeCentric.for_shards(), Schedule::EdgeCentric);
    }

    #[test]
    fn static_splits_evenly() {
        let ch = Schedule::Static.chunks(100, 4, None);
        assert_eq!(ch, vec![0..25, 25..50, 50..75, 75..100]);
        covers_exactly(&ch, 100).unwrap();
    }

    #[test]
    fn dynamic_chunk_sizes() {
        let ch = Schedule::Dynamic { chunk: 30 }.chunks(100, 4, None);
        assert_eq!(ch, vec![0..30, 30..60, 60..90, 90..100]);
    }

    #[test]
    fn guided_chunks_shrink() {
        let ch = Schedule::Guided { min_chunk: 1 }.chunks(1000, 4, None);
        covers_exactly(&ch, 1000).unwrap();
        // First chunk is remaining/threads = 250; sizes never grow.
        assert_eq!(ch[0], 0..250);
        let sizes: Vec<usize> = ch.iter().map(|r| r.len()).collect();
        for w in sizes.windows(2) {
            assert!(w[1] <= w[0]);
        }
    }

    #[test]
    fn edge_centric_balances_edges_not_items() {
        // 9 light vertices (degree 1) + 1 heavy (degree 91): static would
        // give thread 0 the heavy one plus others; edge-centric isolates it.
        let mut w = vec![1u64; 9];
        w.push(91);
        let ch = Schedule::EdgeCentric.chunks(10, 2, Some(&w));
        assert_eq!(ch.len(), 2);
        covers_exactly(&ch, 10).unwrap();
        let edge_load: Vec<u64> = ch
            .iter()
            .map(|r| r.clone().map(|i| w[i]).sum::<u64>())
            .collect();
        // Perfect balance impossible (one item holds 91%), but the light
        // items must all land in the first part: cuts at the 50% edge mark.
        assert_eq!(ch[0], 0..9);
        assert_eq!(ch[1], 9..10);
        assert_eq!(edge_load, vec![9, 91]);
    }

    #[test]
    fn prop_all_schedules_cover_exactly_once() {
        quick::check("schedule coverage", |rng| {
            let n = rng.below(500) as usize;
            let threads = 1 + rng.below(16) as usize;
            let weights = quick::skewed_degrees(rng, n, 64);
            for sched in [
                Schedule::Static,
                Schedule::Dynamic {
                    chunk: 1 + rng.below(64) as usize,
                },
                Schedule::Guided {
                    min_chunk: 1 + rng.below(8) as usize,
                },
                Schedule::EdgeCentric,
            ] {
                let ch = sched.chunks(n, threads, Some(&weights));
                covers_exactly(&ch, n).map_err(|e| format!("{sched:?}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_edge_centric_parts_within_one_max_degree_of_ideal() {
        quick::check("edge-centric balance", |rng| {
            let n = 1 + rng.below(400) as usize;
            let threads = 1 + rng.below(8) as usize;
            let w = quick::skewed_degrees(rng, n, 128);
            let total: u64 = w.iter().sum();
            let maxw = *w.iter().max().unwrap();
            let ideal = total as f64 / threads as f64;
            let ch = Schedule::EdgeCentric.chunks(n, threads, Some(&w));
            for r in &ch {
                let load: u64 = r.clone().map(|i| w[i]).sum();
                if load as f64 > ideal + maxw as f64 {
                    return Err(format!(
                        "part {r:?} load {load} exceeds ideal {ideal} + max degree {maxw}"
                    ));
                }
            }
            Ok(())
        });
    }
}
