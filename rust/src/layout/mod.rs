//! Vertex attribute layouts — baseline interleaved vs externalised (§IV).
//!
//! During communication the engine touches only each vertex's *hot*
//! attributes (message slot + flag); everything else — the user value,
//! degrees, activity metadata — is *cold*. The baseline [`AosStore`]
//! interleaves hot and cold in one record per vertex, so every pull of a
//! neighbour's message drags a full record-sized region through the cache.
//! The externalised [`SoaStore`] groups attributes by access frequency:
//! hot slots in their own dense arrays, cold attributes elsewhere, so
//! cache lines carry only useful bytes.
//!
//! Both implement [`VertexStore`]; the engine is generic over it, which is
//! exactly how the optimisation stays invisible to user code.

pub mod aos;
pub mod soa;
pub mod store;

pub use aos::AosStore;
pub use soa::SoaStore;
pub use store::{Layout, SyncCell, VertexMeta, VertexStore};
