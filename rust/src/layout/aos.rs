//! Baseline array-of-structures layout: one interleaved record per vertex.
//!
//! This mirrors the unoptimised iPregel vertex structure, where the hot
//! flag+message pair shares a record (and its cache lines) with the user
//! value and the neighbour metadata. Scanning neighbours' mailboxes
//! therefore loads mostly-useless bytes — the §IV problem.

use crate::combine::slot::{MessageValue, MsgSlot};
use crate::graph::csr::{Csr, VertexId};
use crate::layout::store::{Layout, SyncCell, VertexMeta, VertexStore};

/// One interleaved vertex record. The two epoch slots sit between the
/// cold fields, as in the original struct.
struct Record<V, M: MessageValue> {
    value: SyncCell<V>,
    meta: VertexMeta,
    slot_a: MsgSlot<M>,
    slot_b: MsgSlot<M>,
}

/// Baseline interleaved store.
pub struct AosStore<V, M: MessageValue> {
    records: Vec<Record<V, M>>,
    /// Which slot is the *current* epoch: false → `slot_a`, true → `slot_b`.
    flipped: bool,
    /// Graph mutation epoch the contents were last primed against.
    epoch_tag: u64,
}

impl<V: Send + Sync, M: MessageValue> VertexStore<V, M> for AosStore<V, M> {
    fn build(g: &Csr, init: &mut dyn FnMut(VertexId) -> V) -> Self {
        let records = g
            .vertices()
            .map(|v| Record {
                value: SyncCell::new(init(v)),
                meta: VertexMeta::of(g, v),
                slot_a: MsgSlot::new(),
                slot_b: MsgSlot::new(),
            })
            .collect();
        AosStore {
            records,
            flipped: false,
            epoch_tag: 0,
        }
    }

    fn reset(&mut self, g: &Csr, init: &mut dyn FnMut(VertexId) -> V) {
        debug_assert_eq!(self.records.len(), g.num_vertices());
        for (v, r) in self.records.iter_mut().enumerate() {
            *r.value.get_mut() = init(v as VertexId);
            r.slot_a.clear();
            r.slot_b.clear();
        }
        self.flipped = false;
    }

    fn reset_range(&mut self, range: std::ops::Range<usize>, init: &mut dyn FnMut(VertexId) -> V) {
        for v in range {
            let r = &mut self.records[v];
            *r.value.get_mut() = init(v as VertexId);
            r.slot_a.clear();
            r.slot_b.clear();
        }
    }

    fn rewind_epochs(&mut self) {
        self.flipped = false;
    }

    #[inline]
    fn epoch_tag(&self) -> u64 {
        self.epoch_tag
    }

    fn set_epoch_tag(&mut self, epoch: u64) {
        self.epoch_tag = epoch;
    }

    #[inline]
    fn len(&self) -> usize {
        self.records.len()
    }

    #[inline]
    fn value(&self, v: VertexId) -> &V {
        self.records[v as usize].value.get()
    }

    #[inline]
    fn value_mut(&self, v: VertexId) -> &mut V {
        self.records[v as usize].value.get_mut()
    }

    #[inline]
    fn meta(&self, v: VertexId) -> &VertexMeta {
        &self.records[v as usize].meta
    }

    #[inline]
    fn cur_slot(&self, v: VertexId) -> &MsgSlot<M> {
        let r = &self.records[v as usize];
        if self.flipped {
            &r.slot_b
        } else {
            &r.slot_a
        }
    }

    #[inline]
    fn next_slot(&self, v: VertexId) -> &MsgSlot<M> {
        let r = &self.records[v as usize];
        if self.flipped {
            &r.slot_a
        } else {
            &r.slot_b
        }
    }

    fn swap_epochs(&mut self) {
        self.flipped = !self.flipped;
    }

    fn layout(&self) -> Layout {
        Layout::Interleaved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn build_and_access() {
        let g = gen::ring(10);
        let store: AosStore<f64, f64> = AosStore::build(&g, &mut |v| v as f64);
        assert_eq!(store.len(), 10);
        assert_eq!(*store.value(3), 3.0);
        *store.value_mut(3) = 7.5;
        assert_eq!(*store.value(3), 7.5);
        assert_eq!(store.meta(3).out_degree, 2);
        assert_eq!(store.layout(), Layout::Interleaved);
    }

    #[test]
    fn epochs_swap() {
        let g = gen::ring(5);
        let mut store: AosStore<u32, u64> = AosStore::build(&g, &mut |_| 0);
        store.next_slot(2).store_first(99);
        assert_eq!(store.cur_slot(2).peek(), None);
        store.swap_epochs();
        assert_eq!(store.cur_slot(2).peek(), Some(99));
        assert_eq!(store.next_slot(2).peek(), None);
        store.swap_epochs();
        // Back to the original orientation: slot_a never received anything.
        assert_eq!(store.cur_slot(2).peek(), None);
    }

    #[test]
    fn reset_restores_fresh_state_without_realloc() {
        let g = gen::ring(6);
        let mut store: AosStore<u64, u64> = AosStore::build(&g, &mut |v| v as u64);
        store.next_slot(1).store_first(42);
        store.swap_epochs();
        *store.value_mut(1) = 999;
        store.reset(&g, &mut |v| v as u64 + 10);
        assert_eq!(*store.value(1), 11);
        for v in g.vertices() {
            assert_eq!(store.cur_slot(v).peek(), None);
            assert_eq!(store.next_slot(v).peek(), None);
        }
    }

    #[test]
    fn record_is_bigger_than_hot_slot() {
        // The whole point of §IV: the interleaved record wastes cache
        // space relative to the 16-byte hot slot.
        assert!(std::mem::size_of::<Record<f64, f64>>() > 2 * std::mem::size_of::<MsgSlot<f64>>());
    }
}
