//! Externalised structure-of-arrays layout (§IV).
//!
//! Hot attributes — the two epoch slot arrays — live in their own dense
//! allocations; the user value and cold metadata live elsewhere. A pull
//! scan over neighbours' mailboxes now touches only 16-byte slots, so a
//! 64-byte cache line serves four neighbours instead of less than one
//! interleaved record.

use crate::combine::slot::{MessageValue, MsgSlot};
use crate::graph::csr::{Csr, VertexId};
use crate::layout::store::{Layout, SyncCell, VertexMeta, VertexStore};

/// Externalised store: hot slots split from cold attributes.
pub struct SoaStore<V, M: MessageValue> {
    values: Vec<SyncCell<V>>,
    metas: Vec<VertexMeta>,
    slots_a: Vec<MsgSlot<M>>,
    slots_b: Vec<MsgSlot<M>>,
    /// false → `slots_a` is current; true → `slots_b` is current.
    flipped: bool,
    /// Graph mutation epoch the contents were last primed against.
    epoch_tag: u64,
}

impl<V: Send + Sync, M: MessageValue> VertexStore<V, M> for SoaStore<V, M> {
    fn build(g: &Csr, init: &mut dyn FnMut(VertexId) -> V) -> Self {
        let n = g.num_vertices();
        let values = g.vertices().map(|v| SyncCell::new(init(v))).collect();
        let metas = g.vertices().map(|v| VertexMeta::of(g, v)).collect();
        let mut slots_a = Vec::with_capacity(n);
        slots_a.resize_with(n, MsgSlot::new);
        let mut slots_b = Vec::with_capacity(n);
        slots_b.resize_with(n, MsgSlot::new);
        SoaStore {
            values,
            metas,
            slots_a,
            slots_b,
            flipped: false,
            epoch_tag: 0,
        }
    }

    fn reset(&mut self, g: &Csr, init: &mut dyn FnMut(VertexId) -> V) {
        debug_assert_eq!(self.values.len(), g.num_vertices());
        for (v, cell) in self.values.iter_mut().enumerate() {
            *cell.get_mut() = init(v as VertexId);
        }
        for s in &self.slots_a {
            s.clear();
        }
        for s in &self.slots_b {
            s.clear();
        }
        self.flipped = false;
    }

    fn reset_range(&mut self, range: std::ops::Range<usize>, init: &mut dyn FnMut(VertexId) -> V) {
        for v in range.clone() {
            *self.values[v].get_mut() = init(v as VertexId);
        }
        for s in &self.slots_a[range.clone()] {
            s.clear();
        }
        for s in &self.slots_b[range] {
            s.clear();
        }
    }

    fn rewind_epochs(&mut self) {
        self.flipped = false;
    }

    #[inline]
    fn epoch_tag(&self) -> u64 {
        self.epoch_tag
    }

    fn set_epoch_tag(&mut self, epoch: u64) {
        self.epoch_tag = epoch;
    }

    #[inline]
    fn len(&self) -> usize {
        self.values.len()
    }

    #[inline]
    fn value(&self, v: VertexId) -> &V {
        self.values[v as usize].get()
    }

    #[inline]
    fn value_mut(&self, v: VertexId) -> &mut V {
        self.values[v as usize].get_mut()
    }

    #[inline]
    fn meta(&self, v: VertexId) -> &VertexMeta {
        &self.metas[v as usize]
    }

    #[inline]
    fn cur_slot(&self, v: VertexId) -> &MsgSlot<M> {
        if self.flipped {
            &self.slots_b[v as usize]
        } else {
            &self.slots_a[v as usize]
        }
    }

    #[inline]
    fn next_slot(&self, v: VertexId) -> &MsgSlot<M> {
        if self.flipped {
            &self.slots_a[v as usize]
        } else {
            &self.slots_b[v as usize]
        }
    }

    fn swap_epochs(&mut self) {
        self.flipped = !self.flipped;
    }

    fn layout(&self) -> Layout {
        Layout::Externalised
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn build_and_access() {
        let g = gen::star(6);
        let store: SoaStore<u64, u32> = SoaStore::build(&g, &mut |v| v as u64 * 10);
        assert_eq!(store.len(), 6);
        assert_eq!(*store.value(5), 50);
        *store.value_mut(5) = 1;
        assert_eq!(*store.value(5), 1);
        assert_eq!(store.meta(0).out_degree, 5);
        assert_eq!(store.layout(), Layout::Externalised);
    }

    #[test]
    fn epochs_swap() {
        let g = gen::ring(4);
        let mut store: SoaStore<u32, u64> = SoaStore::build(&g, &mut |_| 0);
        store.next_slot(1).store_first(7);
        assert_eq!(store.cur_slot(1).peek(), None);
        store.swap_epochs();
        assert_eq!(store.cur_slot(1).peek(), Some(7));
        assert_eq!(store.next_slot(1).peek(), None);
    }

    #[test]
    fn reset_restores_fresh_state_without_realloc() {
        let g = gen::ring(5);
        let mut store: SoaStore<u32, u32> = SoaStore::build(&g, &mut |v| v);
        store.next_slot(2).store_first(7);
        store.swap_epochs();
        *store.value_mut(2) = 77;
        store.reset(&g, &mut |v| v + 1);
        assert_eq!(*store.value(2), 3);
        for v in g.vertices() {
            assert_eq!(store.cur_slot(v).peek(), None);
            assert_eq!(store.next_slot(v).peek(), None);
        }
    }

    #[test]
    fn reset_range_over_all_shards_matches_full_reset() {
        let g = gen::ring(10);
        let mut full: SoaStore<u32, u32> = SoaStore::build(&g, &mut |v| v);
        let mut ranged: SoaStore<u32, u32> = SoaStore::build(&g, &mut |v| v);
        for s in [&mut full, &mut ranged] {
            s.next_slot(3).store_first(9);
            s.swap_epochs();
            *s.value_mut(3) = 77;
        }
        full.reset(&g, &mut |v| v + 1);
        // Shard-by-shard priming plus an epoch rewind must land in the
        // identical post-state.
        ranged.reset_range(0..4, &mut |v| v + 1);
        ranged.reset_range(4..10, &mut |v| v + 1);
        ranged.rewind_epochs();
        for v in g.vertices() {
            assert_eq!(*full.value(v), *ranged.value(v));
            assert_eq!(full.cur_slot(v).peek(), ranged.cur_slot(v).peek());
            assert_eq!(full.next_slot(v).peek(), ranged.next_slot(v).peek());
        }
    }

    #[test]
    fn hot_slots_are_contiguous() {
        // Consecutive vertices' slots must be adjacent in memory — the
        // cache-efficiency property §IV relies on.
        let g = gen::ring(8);
        let store: SoaStore<u64, f64> = SoaStore::build(&g, &mut |_| 0);
        let p0 = store.cur_slot(0) as *const _ as usize;
        let p1 = store.cur_slot(1) as *const _ as usize;
        assert_eq!(p1 - p0, std::mem::size_of::<MsgSlot<f64>>());
    }

    /// Both layouts must behave identically; only memory placement differs.
    #[test]
    fn semantics_match_aos() {
        use crate::layout::aos::AosStore;
        let g = gen::grid(3, 3);
        let mut a: AosStore<u32, u32> = AosStore::build(&g, &mut |v| v);
        let mut s: SoaStore<u32, u32> = SoaStore::build(&g, &mut |v| v);
        for v in g.vertices() {
            a.next_slot(v).store_first(v + 100);
            s.next_slot(v).store_first(v + 100);
        }
        a.swap_epochs();
        s.swap_epochs();
        for v in g.vertices() {
            assert_eq!(a.cur_slot(v).peek(), s.cur_slot(v).peek());
            assert_eq!(*a.value(v), *s.value(v));
            assert_eq!(a.meta(v).in_degree, s.meta(v).in_degree);
        }
    }
}
