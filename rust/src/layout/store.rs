//! The [`VertexStore`] abstraction shared by both layouts.

use crate::combine::slot::{MessageValue, MsgSlot};
use crate::graph::csr::{Csr, VertexId};
use std::cell::UnsafeCell;

/// Which layout an engine run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout {
    /// Baseline: one interleaved record per vertex (array-of-structures).
    Interleaved,
    /// Externalised hot attributes (§IV, structure-of-arrays).
    Externalised,
}

impl Layout {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Layout> {
        match s {
            "aos" | "interleaved" | "baseline" => Some(Layout::Interleaved),
            "soa" | "externalised" | "externalized" | "extern" => Some(Layout::Externalised),
            _ => None,
        }
    }
}

/// Interior-mutable cell for per-vertex user values. The engine guarantees
/// each vertex is computed by exactly one thread per superstep, which makes
/// the unsynchronised access sound (same discipline iPregel's C code uses).
/// With `--features race-check` every access is recorded in a shadow cell
/// and that discipline is enforced at runtime (see `util::shadow`), at the
/// cost of the transparent layout.
#[cfg_attr(not(feature = "race-check"), repr(transparent))]
pub struct SyncCell<T> {
    inner: UnsafeCell<T>,
    #[cfg(feature = "race-check")]
    shadow: crate::util::shadow::ShadowCell,
}

// SAFETY: `SyncCell` hands out unsynchronised references, which is sound
// only under the engine's phase discipline — at most one thread accesses a
// given cell per parallel phase, and phases are separated by scope joins
// (documented above; machine-checked under `race-check`). `T: Send` is
// required because cells move between threads across phases.
unsafe impl<T: Send> Sync for SyncCell<T> {}

impl<T> SyncCell<T> {
    /// Wrap a value.
    pub fn new(v: T) -> Self {
        SyncCell {
            inner: UnsafeCell::new(v),
            #[cfg(feature = "race-check")]
            shadow: crate::util::shadow::ShadowCell::new(),
        }
    }

    /// Shared read. Sound while no thread holds `get_mut` on the same
    /// vertex — the engine's per-vertex ownership discipline.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn get(&self) -> &T {
        #[cfg(feature = "race-check")]
        self.shadow.on_read(crate::util::shadow::Site::CellGet);
        // SAFETY: shared reads are only issued in phases where no thread
        // writes this cell (enforced by the shadow record under
        // `race-check`), so no `&mut` aliases the returned `&T`.
        unsafe { &*self.inner.get() }
    }

    /// Exclusive write handle (engine-enforced exclusivity per vertex).
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn get_mut(&self) -> &mut T {
        #[cfg(feature = "race-check")]
        self.shadow
            .on_write(crate::util::shadow::Site::CellGetMut, false);
        // SAFETY: the engine assigns each vertex to exactly one thread per
        // phase, so this is the only live reference to the cell for the
        // duration of the phase (enforced by the shadow record under
        // `race-check`).
        unsafe { &mut *self.inner.get() }
    }
}

/// Cold per-vertex metadata a realistic vertex-centric framework carries in
/// its vertex structure (iPregel's has id, neighbour pointers and counts).
/// The baseline layout interleaves this with the hot slots — faithfully
/// reproducing the cache pollution the paper measures. The cached degrees
/// and offsets describe the **base** CSR arrays (nothing reads them on the
/// compute path); on a mutated graph the live values come from the
/// overlay-aware `Csr` accessors.
#[derive(Clone, Copy, Debug, Default)]
pub struct VertexMeta {
    /// Vertex id (iPregel stores it; useful for debugging/dumps).
    pub id: VertexId,
    /// Cached out-degree.
    pub out_degree: u32,
    /// Cached in-degree.
    pub in_degree: u32,
    /// Offset of this vertex's row in the CSR out-targets array.
    pub out_offset: u64,
    /// Offset of this vertex's row in the CSR in-sources array.
    pub in_offset: u64,
}

impl VertexMeta {
    /// Build metadata for vertex `v` of `g`.
    pub fn of(g: &Csr, v: VertexId) -> Self {
        VertexMeta {
            id: v,
            out_degree: g.out_degree(v) as u32,
            in_degree: g.in_degree(v) as u32,
            out_offset: g.out_offsets[v as usize] as u64,
            in_offset: g.in_offsets[v as usize] as u64,
        }
    }
}

/// Storage of per-vertex state: user value `V`, cold metadata, and two
/// epochs of message slots (`cur` = read by this superstep's compute,
/// `next` = written by this superstep's sends; swapped at the barrier).
///
/// The slots are the **combined delivery plane's** mailboxes. Log-plane
/// runs (`combine/plane.rs`) leave them untouched — their messages live
/// in a session-pooled `MessageLog` instead — but the store's values,
/// metadata and epoch flip serve both planes unchanged.
pub trait VertexStore<V: Send, M: MessageValue>: Send + Sync {
    /// Build a store for graph `g`, initialising each value with `init`.
    fn build(g: &Csr, init: &mut dyn FnMut(VertexId) -> V) -> Self
    where
        Self: Sized;

    /// Re-prime an existing store for a fresh run on the *same* graph:
    /// re-initialise every value with `init`, clear both epoch slots and
    /// reset the epoch flip — without reallocating any of the slabs. This
    /// is what lets a [`crate::engine::GraphSession`] amortise store
    /// allocations across runs.
    fn reset(&mut self, g: &Csr, init: &mut dyn FnMut(VertexId) -> V);

    /// Re-prime one contiguous vertex range — a partition shard's slab —
    /// leaving the epoch flip untouched. Partitioned sessions prime a
    /// pooled store shard-by-shard so each shard's values and slots are
    /// written as one contiguous sweep (warming the slab the scatter
    /// phase will own); callers follow up with [`VertexStore::rewind_epochs`]
    /// once all shards are primed. The post-state of priming every shard
    /// plus a rewind is identical to [`VertexStore::reset`].
    fn reset_range(&mut self, range: std::ops::Range<usize>, init: &mut dyn FnMut(VertexId) -> V);

    /// Reset the epoch flip to its initial orientation (companion of
    /// [`VertexStore::reset_range`]; [`VertexStore::reset`] includes it).
    fn rewind_epochs(&mut self);

    /// The graph **mutation epoch** this store's contents were last
    /// primed against (see `graph/dynamic.rs`). Freshly built stores
    /// report 0; sessions re-stamp pooled stores at checkout and use a
    /// mismatch to flag (and re-prime away) state from an older epoch —
    /// the epoch-tagged extension of the rewind machinery above.
    fn epoch_tag(&self) -> u64;

    /// Stamp the store with the mutation epoch it is being primed for.
    fn set_epoch_tag(&mut self, epoch: u64);

    /// Number of vertices.
    fn len(&self) -> usize;

    /// True when the store holds no vertices.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Shared borrow of `v`'s user value.
    fn value(&self, v: VertexId) -> &V;

    /// Exclusive borrow of `v`'s user value (engine guarantees one thread
    /// per vertex per superstep).
    #[allow(clippy::mut_from_ref)]
    fn value_mut(&self, v: VertexId) -> &mut V;

    /// Cold metadata of `v`.
    fn meta(&self, v: VertexId) -> &VertexMeta;

    /// Current-epoch slot (messages delivered *last* superstep).
    fn cur_slot(&self, v: VertexId) -> &MsgSlot<M>;

    /// Next-epoch slot (messages being delivered *this* superstep).
    fn next_slot(&self, v: VertexId) -> &MsgSlot<M>;

    /// Flip epochs at the superstep barrier (single-threaded phase).
    fn swap_epochs(&mut self);

    /// Which layout this store implements (for reporting).
    fn layout(&self) -> Layout;
}
