//! Minimal CLI argument parsing (the offline build has no `clap`).
//!
//! `Opts` splits a flat argv into positional arguments and `--key value` /
//! `--flag` options, with typed accessors that produce helpful errors.
//! Shared by the `ipregel` binary and the examples.

use crate::util::error::Result;
use crate::{bail, err};
use std::collections::BTreeMap;

/// Parsed command-line options.
#[derive(Debug, Clone, Default)]
pub struct Opts {
    /// Positional (non-flag) arguments in order.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Marker value for boolean flags given without an argument.
const FLAG_SET: &str = "\u{1}true";

impl Opts {
    /// Parse an argv slice. A token `--k` consumes the next token as its
    /// value unless that token is itself a flag (then `--k` is boolean).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Opts {
        let args: Vec<String> = args.into_iter().collect();
        let mut opts = Opts::default();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let takes_value = i + 1 < args.len() && !args[i + 1].starts_with("--");
                if takes_value {
                    opts.flags.insert(key.to_string(), args[i + 1].clone());
                    i += 2;
                } else {
                    opts.flags.insert(key.to_string(), FLAG_SET.to_string());
                    i += 1;
                }
            } else {
                opts.positional.push(a.clone());
                i += 1;
            }
        }
        opts
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str()).filter(|s| *s != FLAG_SET)
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Boolean flag (present with no value, or `true`/`false`).
    pub fn flag(&self, key: &str) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some(FLAG_SET) | Some("true") | Some("1") => true,
            Some("false") | Some("0") | None => false,
            Some(_) => true,
        }
    }

    /// Parsed numeric option.
    pub fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| err!("--{key}: cannot parse '{v}'")),
        }
    }

    /// Error on unknown flags (catches typos early).
    pub fn ensure_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                bail!("unknown option --{k} (known: {})", known.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Opts {
        Opts::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags_separate() {
        let o = parse("run --threads 8 graph.ipg --bypass");
        assert_eq!(o.positional, vec!["run", "graph.ipg"]);
        assert_eq!(o.get("threads"), Some("8"));
        assert!(o.flag("bypass"));
        assert!(!o.flag("absent"));
    }

    #[test]
    fn numeric_parsing_and_defaults() {
        let o = parse("--threads 8");
        assert_eq!(o.get_num("threads", 4usize).unwrap(), 8);
        assert_eq!(o.get_num("chunk", 256usize).unwrap(), 256);
        let bad = parse("--threads eight");
        assert!(bad.get_num("threads", 4usize).is_err());
    }

    #[test]
    fn boolean_flag_before_another_flag() {
        let o = parse("--bypass --threads 2");
        assert!(o.flag("bypass"));
        assert_eq!(o.get("threads"), Some("2"));
    }

    #[test]
    fn ensure_known_catches_typos() {
        let o = parse("--theads 8");
        assert!(o.ensure_known(&["threads"]).is_err());
        assert!(o.ensure_known(&["theads", "threads"]).is_ok());
    }

    #[test]
    fn explicit_false_is_false() {
        let o = parse("--bypass false");
        assert!(!o.flag("bypass"));
    }
}
