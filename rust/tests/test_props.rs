//! Property-based integration tests over randomised graphs and
//! configurations (seeded; replay any failure with the printed
//! `QUICK_SEED`).

use ipregel::algos::{reference, ConnectedComponents, Lpa, PageRank, Sssp, Triangles, WeightedSssp};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession};
use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
use ipregel::graph::gen;
use ipregel::graph::GraphBuilder;
use ipregel::layout::Layout;
use ipregel::sched::Schedule;
use ipregel::util::quick;
use ipregel::util::rng::Rng;

fn random_cfg(rng: &mut Rng) -> EngineConfig {
    let schedules = [
        Schedule::Static,
        Schedule::Dynamic {
            chunk: 1 + rng.below(128) as usize,
        },
        Schedule::Guided {
            min_chunk: 1 + rng.below(16) as usize,
        },
        Schedule::EdgeCentric,
    ];
    let strategies = [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid];
    let layouts = [Layout::Interleaved, Layout::Externalised];
    EngineConfig::default()
        .threads(1 + rng.below(6) as usize)
        .schedule(schedules[rng.below(4) as usize])
        .strategy(strategies[rng.below(3) as usize])
        .layout(layouts[rng.below(2) as usize])
        .bypass(rng.chance(0.5))
        // 0 = flat substrate; otherwise the partitioned scatter/flush
        // path, which must be behaviourally indistinguishable.
        .shards(rng.below(5) as usize)
}

fn random_graph(rng: &mut Rng) -> ipregel::graph::Csr {
    let n = 2 + rng.below(300) as usize;
    let m = rng.below(4 * n as u64) as usize;
    let edges = quick::random_edges(rng, n, m);
    GraphBuilder::new(n)
        .symmetric(rng.chance(0.7))
        .dedup(rng.chance(0.5))
        .drop_self_loops(true)
        .edges(&edges)
        .build()
}

#[test]
fn prop_pagerank_mass_and_reference_agreement() {
    quick::check("pagerank properties", |rng| {
        let g = random_graph(rng);
        let cfg = random_cfg(rng);
        let iters = rng.below(6) as usize;
        let p = PageRank {
            iterations: iters,
            damping: 0.85,
        };
        let got = GraphSession::with_config(&g, cfg).run(&p);
        // Mass never exceeds 1 (dangling mass only leaks out).
        let total: f64 = got.values.iter().sum();
        if total > 1.0 + 1e-9 {
            return Err(format!("mass {total} > 1 under {cfg:?}"));
        }
        if got.values.iter().any(|&r| !(r > 0.0) || !r.is_finite()) {
            return Err("non-positive or non-finite rank".into());
        }
        let want = reference::pagerank(&g, iters, 0.85);
        for v in g.vertices() {
            let (a, b) = (got.values[v as usize], want[v as usize]);
            if (a - b).abs() > 1e-11 {
                return Err(format!("v{v}: {a} vs {b} under {cfg:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cc_fixpoint_and_reference_agreement() {
    quick::check("cc properties", |rng| {
        // CC via min-label propagation assumes an undirected graph (all
        // of the paper's Table I graphs are), so force symmetry here.
        let n = 2 + rng.below(300) as usize;
        let m = rng.below(4 * n as u64) as usize;
        let edges = quick::random_edges(rng, n, m);
        let g = GraphBuilder::new(n)
            .symmetric(true)
            .drop_self_loops(true)
            .edges(&edges)
            .build();
        let cfg = random_cfg(rng);
        let got = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
        let want = reference::connected_components(&g);
        if got.values != want {
            return Err(format!("labels differ under {cfg:?}"));
        }
        // Fixpoint: every vertex label ≤ all neighbours' labels would be
        // wrong (labels are equal within a component); check equality
        // along every edge instead.
        for (s, d) in g.edges() {
            if got.values[s as usize] != got.values[d as usize] {
                return Err(format!("edge ({s},{d}) crosses labels"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sssp_triangle_inequality_and_reference() {
    quick::check("sssp properties", |rng| {
        let g = random_graph(rng);
        let cfg = random_cfg(rng);
        let source = rng.below(g.num_vertices() as u64) as u32;
        let got = GraphSession::with_config(&g, cfg).run(&Sssp { source });
        let want = reference::bfs_levels(&g, source);
        if got.values != want {
            return Err(format!("distances differ under {cfg:?} source {source}"));
        }
        // Edge relaxation invariant: d(v) ≤ d(u) + 1 for every edge u→v.
        for (u, v) in g.edges() {
            let (du, dv) = (got.values[u as usize], got.values[v as usize]);
            if du != u64::MAX && dv > du + 1 {
                return Err(format!("edge ({u},{v}): d={du} then {dv}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_weighted_sssp_matches_dijkstra() {
    quick::check("weighted sssp vs dijkstra", |rng| {
        let base = random_graph(rng);
        let g = ipregel::graph::gen::randomly_weighted(&base, 0.1, 10.0, rng.next_u64());
        let cfg = random_cfg(rng);
        let source = rng.below(g.num_vertices() as u64) as u32;
        let got = GraphSession::with_config(&g, cfg).run(&WeightedSssp { source });
        let want = reference::dijkstra(&g, source);
        for v in g.vertices() {
            let (a, b) = (got.values[v as usize], want[v as usize]);
            let ok = (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9;
            if !ok {
                return Err(format!("v{v}: {a} vs {b} under {cfg:?} source {source}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lpa_matches_serial_reference_across_engine_grid() {
    // Label propagation is non-combinable (mode of the neighbour-label
    // multiset): it runs on the log delivery plane. The engine must
    // match the serial reference under every Strategy × Layout ×
    // Schedule × Partitioning × bypass combination — including the
    // partitioned substrate, where cross-shard log messages batch-route
    // through the remote buffers.
    quick::check("lpa vs serial reference", |rng| {
        let g = random_graph(rng);
        let cfg = random_cfg(rng);
        let rounds = rng.below(5) as usize;
        let p = Lpa { rounds };
        let got = GraphSession::with_config(&g, cfg).run(&p);
        let want = reference::lpa(&g, rounds);
        if got.values != want {
            return Err(format!("labels differ under {cfg:?} rounds {rounds}"));
        }
        // The log plane's defining property: nothing is folded.
        let m = &got.metrics;
        if m.retained_messages != m.total_messages() || m.combined_messages != 0 {
            return Err(format!(
                "log plane folded messages under {cfg:?}: retained {} of {}",
                m.retained_messages,
                m.total_messages()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_triangles_match_serial_reference_across_engine_grid() {
    quick::check("triangles vs serial reference", |rng| {
        // Simple undirected graph — the program's documented contract.
        let n = 2 + rng.below(150) as usize;
        let m = rng.below(3 * n as u64) as usize;
        let edges = quick::random_edges(rng, n, m);
        let g = GraphBuilder::new(n)
            .symmetric(true)
            .dedup(true)
            .drop_self_loops(true)
            .edges(&edges)
            .build();
        let cfg = random_cfg(rng);
        let got = GraphSession::with_config(&g, cfg).run(&Triangles);
        let want = reference::triangles(&g);
        if got.values != want {
            return Err(format!("counts differ under {cfg:?}"));
        }
        let total: u64 = got.values.iter().sum();
        if total % 3 != 0 {
            return Err(format!("corner total {total} not divisible by 3"));
        }
        Ok(())
    });
}

#[test]
fn log_plane_algos_match_references_on_catalog_graphs_flat_and_sharded() {
    // The acceptance grid: lpa and triangles against their serial
    // references on a catalog analogue, flat and partitioned.
    let entry = ipregel::graph::catalog::find("dblp-t").expect("catalog entry");
    let g = entry.generate();
    let p = Lpa { rounds: 3 };
    let want_lpa = reference::lpa(&g, 3);
    for shards in [0usize, 6] {
        let cfg = EngineConfig::default().threads(4).shards(shards);
        let got = GraphSession::with_config(&g, cfg).run(&p);
        assert_eq!(got.values, want_lpa, "lpa shards={shards}");
    }
    // Triangle counting runs on the simple symmetric closure.
    let edges: Vec<(u32, u32)> = g.edges().collect();
    let tg = GraphBuilder::new(g.num_vertices())
        .symmetric(true)
        .dedup(true)
        .drop_self_loops(true)
        .edges(&edges)
        .build();
    let want_tri = reference::triangles(&tg);
    for shards in [0usize, 6] {
        let cfg = EngineConfig::default().threads(4).shards(shards);
        let got = GraphSession::with_config(&tg, cfg).run(&Triangles);
        assert_eq!(got.values, want_tri, "triangles shards={shards}");
    }
}

#[test]
fn prop_delta_merged_out_edges_match_rebuilt_csr() {
    // Weighted-edge parity under mutation: after arbitrary insert/delete
    // batches (with optional forced compaction), delta-merged
    // `out_edge`/`in_edge` iteration must yield the same (neighbour,
    // weight) multiset — in fact the same sequence — as a CSR rebuilt
    // from the surviving edge list.
    quick::check("delta-merged out_edge == rebuilt CSR", |rng| {
        let n = 2 + rng.below(60) as usize;
        let m0 = rng.below(4 * n as u64) as usize;
        let weighted = rng.chance(0.5);
        let mut gb = GraphBuilder::new(n);
        for (s, d) in quick::random_edges(rng, n, m0) {
            if weighted {
                gb.push_weighted_edge(s, d, (1 + rng.below(64)) as f64 / 8.0);
            } else {
                gb.push_edge(s, d);
            }
        }
        let threshold = if rng.chance(0.3) {
            1 + rng.below(8) as usize
        } else {
            1_000_000
        };
        let mut dg = DynamicGraph::with_spill_threshold(gb.build(), threshold);
        for _ in 0..(1 + rng.below(3)) {
            let mut m = MutationSet::new();
            for _ in 0..rng.below(8) {
                let (s, d) = (rng.below(n as u64) as u32, rng.below(n as u64) as u32);
                if weighted {
                    m.insert_weighted(s, d, (1 + rng.below(64)) as f64 / 8.0);
                } else {
                    m.insert(s, d);
                }
            }
            for _ in 0..rng.below(4) {
                let g = dg.graph();
                if g.num_edges() > 0 && rng.chance(0.6) {
                    let v = (0..n as u32).find(|&v| g.out_degree(v) > 0).unwrap();
                    let d = g.out_neighbors(v)[rng.below(g.out_degree(v) as u64) as usize];
                    m.delete(v, d);
                } else {
                    m.delete(rng.below(n as u64) as u32, rng.below(n as u64) as u32);
                }
            }
            dg.apply(&m);
        }
        let g = dg.graph();
        g.validate()?;
        let rebuilt = g.rebuilt();
        if g.num_edges() != rebuilt.num_edges() {
            return Err("edge counts diverged".into());
        }
        for v in rebuilt.vertices() {
            let got: Vec<_> = (0..g.out_degree(v)).map(|i| g.out_edge(v, i)).collect();
            let want: Vec<_> = (0..rebuilt.out_degree(v))
                .map(|i| rebuilt.out_edge(v, i))
                .collect();
            if got != want {
                return Err(format!("out row v{v}: {got:?} vs {want:?}"));
            }
            let got_in: Vec<_> = (0..g.in_degree(v)).map(|i| g.in_edge(v, i)).collect();
            let want_in: Vec<_> = (0..rebuilt.in_degree(v))
                .map(|i| rebuilt.in_edge(v, i))
                .collect();
            if got_in != want_in {
                return Err(format!("in row v{v}: {got_in:?} vs {want_in:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_engine_results_identical_on_dynamic_and_rebuilt_graphs() {
    // Random configuration, random mutations: mutate→run equals
    // rebuild→run for a pull program (PageRank) — the end-to-end version
    // of the row-parity property above.
    quick::check("dynamic run == rebuilt run", |rng| {
        let n = 4 + rng.below(120) as usize;
        let edges = quick::random_edges(rng, n, rng.below(4 * n as u64) as usize);
        let base = GraphBuilder::new(n)
            .symmetric(true)
            .drop_self_loops(true)
            .edges(&edges)
            .build();
        let mut dg = DynamicGraph::with_spill_threshold(base, 1_000_000);
        let mut m = MutationSet::new();
        for _ in 0..(1 + rng.below(6)) {
            let (s, d) = (rng.below(n as u64) as u32, rng.below(n as u64) as u32);
            if s != d {
                m.insert_undirected(s, d);
            }
        }
        dg.apply(&m);
        let g = dg.graph();
        let rebuilt = g.rebuilt();
        let cfg = random_cfg(rng);
        let iters = rng.below(5) as usize;
        let p = PageRank {
            iterations: iters,
            damping: 0.85,
        };
        let a = GraphSession::with_config(g, cfg).run(&p);
        let b = GraphSession::with_config(&rebuilt, cfg).run(&p);
        if a.values != b.values {
            return Err(format!("pagerank diverged under {cfg:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_structured_graphs_have_known_answers() {
    quick::check("structured graph answers", |rng| {
        // Grid: CC = single component; SSSP from corner = Manhattan.
        let rows = 2 + rng.below(10) as usize;
        let cols = 2 + rng.below(10) as usize;
        let g = gen::grid(rows, cols);
        let cfg = random_cfg(rng);
        let session = GraphSession::with_config(&g, cfg);
        let cc = session.run(&ConnectedComponents);
        if cc.values.iter().any(|&l| l != 0) {
            return Err("grid must be one component".into());
        }
        let ss = session.run(&Sssp { source: 0 });
        for r in 0..rows {
            for c in 0..cols {
                let want = (r + c) as u64;
                if ss.values[r * cols + c] != want {
                    return Err(format!("grid ({r},{c}): {}", ss.values[r * cols + c]));
                }
            }
        }
        Ok(())
    });
}
