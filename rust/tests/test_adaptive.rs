//! Adaptive-tuner integration tests.
//!
//! Two contracts:
//!
//! 1. **Bit-identity** — adaptive runs produce bit-identical values AND
//!    identical superstep traces (active counts, message totals, halt
//!    reason) to the same config run fixed, across the Strategy × Layout
//!    × Schedule × Partitioning × bypass grid. Every knob the tuner
//!    moves is an execution knob; none may change what programs observe.
//! 2. **It actually adapts** — a single-source BFS on a catalog analogue
//!    must record ≥ 2 distinct (schedule, strategy, bypass) modes in its
//!    decision trace, switch at least once mid-run, and never flip-flop
//!    (per-knob dwell ≥ `DecisionTable::dwell` supersteps).

use ipregel::algos::{Bfs, ConnectedComponents, Lpa, PageRank, Sssp};
use ipregel::combine::Strategy;
use ipregel::engine::{DecisionTable, EngineConfig, GraphSession, RunOptions};
use ipregel::graph::catalog;
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::metrics::{distinct_modes, RunMetrics, TunerDecision};
use ipregel::sched::Schedule;

/// The dblp analogue at CI scale (BA, 4 954 vertices) — generated
/// directly, no disk cache involved.
fn catalog_analogue() -> ipregel::graph::csr::Csr {
    catalog::catalog_tiny()[0].generate()
}

fn assert_same_trace(fixed: &RunMetrics, adaptive: &RunMetrics, what: &str) {
    assert_eq!(
        fixed.num_supersteps(),
        adaptive.num_supersteps(),
        "{what}: superstep count"
    );
    for (i, (a, b)) in fixed
        .supersteps
        .iter()
        .zip(adaptive.supersteps.iter())
        .enumerate()
    {
        assert_eq!(
            a.active_vertices, b.active_vertices,
            "{what}: active count at superstep {i}"
        );
        assert_eq!(a.messages, b.messages, "{what}: messages at superstep {i}");
    }
    assert_eq!(fixed.halt_reason, adaptive.halt_reason, "{what}: halt reason");
}

#[test]
fn adaptive_bit_identical_to_fixed_across_the_grid() {
    let g = gen::rmat(8, 5, 0.57, 0.19, 0.19, 2);
    let session = GraphSession::new(&g);
    for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[Schedule::Static, Schedule::EdgeCentric] {
                for &bypass in &[false, true] {
                    for &shards in &[0usize, 3] {
                        let cfg = EngineConfig::default()
                            .threads(4)
                            .strategy(strategy)
                            .layout(layout)
                            .schedule(schedule)
                            .bypass(bypass)
                            .shards(shards);
                        let what = format!("{cfg:?}");

                        let fixed =
                            session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
                        let adaptive = session.run_with(
                            &ConnectedComponents,
                            RunOptions::new().config(cfg.adaptive(true)),
                        );
                        assert_eq!(adaptive.values, fixed.values, "cc values under {what}");
                        assert_same_trace(
                            &fixed.metrics,
                            &adaptive.metrics,
                            &format!("cc under {what}"),
                        );

                        let p = Sssp::from_hub(&g);
                        let fixed = session.run_with(&p, RunOptions::new().config(cfg));
                        let adaptive =
                            session.run_with(&p, RunOptions::new().config(cfg.adaptive(true)));
                        assert_eq!(adaptive.values, fixed.values, "sssp values under {what}");
                        assert_same_trace(
                            &fixed.metrics,
                            &adaptive.metrics,
                            &format!("sssp under {what}"),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn adaptive_pagerank_is_bitwise_identical_flat_and_sharded() {
    // Pull mode folds in-neighbour outboxes in deterministic order, so
    // even f64 ranks must match bit for bit.
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 7);
    let session = GraphSession::new(&g);
    for cfg in [
        EngineConfig::default(),
        EngineConfig::default().bypass(true),
        EngineConfig::default().shards(4),
    ] {
        let fixed = session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
        let adaptive = session.run_with(
            &PageRank::default(),
            RunOptions::new().config(cfg.adaptive(true)),
        );
        assert_eq!(adaptive.values, fixed.values, "under {cfg:?}");
        assert_same_trace(&fixed.metrics, &adaptive.metrics, &format!("{cfg:?}"));
    }
}

#[test]
fn adaptive_bfs_on_catalog_analogue_switches_modes() {
    let g = catalog_analogue();
    let root = g.max_out_degree_vertex();
    let p = Bfs { root };
    let session = GraphSession::new(&g);

    let fixed = session.run(&p);
    let adaptive = session.run_with(
        &p,
        RunOptions::new().config(session.config().adaptive(true)),
    );
    assert_eq!(adaptive.values, fixed.values, "adaptive BFS must stay exact");
    assert_same_trace(&fixed.metrics, &adaptive.metrics, "bfs on dblp-t");

    let trace = &adaptive.metrics.tuner_decisions;
    assert_eq!(
        trace.len(),
        adaptive.metrics.num_supersteps(),
        "one decision per superstep"
    );
    // The acceptance bar: a single-source BFS sweeps sparse → dense →
    // sparse, so the trace must show at least two distinct modes and at
    // least one mid-run switch.
    assert!(
        distinct_modes(trace) >= 2,
        "expected >= 2 distinct modes, trace: {trace:?}"
    );
    assert!(
        trace.iter().any(|d| d.switched),
        "expected a mid-run switch, trace: {trace:?}"
    );
    // Superstep 0 runs the configured plan verbatim (no signals yet).
    assert_eq!(
        trace[0].mode(),
        (Schedule::Static, Strategy::Lock, false),
        "superstep 0 is the configured base plan"
    );
    // The single-vertex frontier must have pushed superstep 1 onto the
    // active list (density 1/|V| is far below any list threshold).
    assert!(trace[1].bypass, "sparse frontier must select the list");
}

#[test]
fn adaptive_bfs_switches_on_the_sharded_substrate_too() {
    let g = catalog_analogue();
    let root = g.max_out_degree_vertex();
    let p = Bfs { root };
    let session = GraphSession::new(&g);
    let cfg = session.config().shards(4);
    let fixed = session.run_with(&p, RunOptions::new().config(cfg));
    let adaptive = session.run_with(&p, RunOptions::new().config(cfg.adaptive(true)));
    assert_eq!(adaptive.values, fixed.values);
    assert_same_trace(&fixed.metrics, &adaptive.metrics, "sharded bfs");
    assert!(distinct_modes(&adaptive.metrics.tuner_decisions) >= 2);
    // The flush-imbalance signal is only defined here: every decision
    // must carry a finite, >= 1.0 reading.
    for d in &adaptive.metrics.tuner_decisions {
        assert!(d.flush_imbalance >= 1.0, "{d:?}");
    }
}

#[test]
fn tuner_never_flip_flops_within_the_dwell_window() {
    let dwell = DecisionTable::default().dwell;
    let g = catalog_analogue();
    let p = Bfs {
        root: g.max_out_degree_vertex(),
    };
    let session = GraphSession::new(&g);
    let r = session.run_with(
        &p,
        RunOptions::new().config(session.config().adaptive(true)),
    );
    let trace = &r.metrics.tuner_decisions;
    // For each knob: once it changes at superstep i, it must hold its new
    // value for at least `dwell` decisions.
    let knobs: [fn(&TunerDecision) -> u64; 3] = [
        |d| d.bypass as u64,
        |d| match d.schedule {
            Schedule::Static => 0,
            Schedule::Dynamic { .. } => 1,
            Schedule::Guided { .. } => 2,
            Schedule::EdgeCentric => 3,
        },
        |d| match d.strategy {
            Strategy::Lock => 0,
            Strategy::CasNeutral => 1,
            Strategy::Hybrid => 2,
        },
    ];
    for knob in knobs {
        let mut last_change: Option<usize> = None;
        for i in 1..trace.len() {
            if knob(&trace[i]) != knob(&trace[i - 1]) {
                if let Some(prev) = last_change {
                    assert!(
                        i - prev >= dwell,
                        "knob changed at {prev} and again at {i} (dwell {dwell}): {trace:?}"
                    );
                }
                last_change = Some(i);
            }
        }
    }
}

#[test]
fn adaptive_composes_with_log_plane_and_cas_neutral() {
    // Log plane: the strategy knob is frozen (no combiner to combine
    // with), but bypass/schedule still adapt and results stay exact.
    let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 9);
    let session = GraphSession::new(&g);
    let p = Lpa { rounds: 4 };
    let fixed = session.run(&p);
    let adaptive = session.run_with(
        &p,
        RunOptions::new().config(session.config().adaptive(true)),
    );
    assert_eq!(adaptive.values, fixed.values, "adaptive LPA");
    for d in &adaptive.metrics.tuner_decisions {
        assert_eq!(d.strategy, Strategy::Lock, "log plane never re-selects strategy");
    }

    // CasNeutral changes the slot representation: the tuner must never
    // leave it, under any signal.
    let cfg = session.config().strategy(Strategy::CasNeutral).adaptive(true);
    let p = Sssp::from_hub(&g);
    let r = session.run_with(&p, RunOptions::new().config(cfg));
    let want = session.run(&p);
    assert_eq!(r.values, want.values);
    for d in &r.metrics.tuner_decisions {
        assert_eq!(d.strategy, Strategy::CasNeutral, "{d:?}");
    }
}

#[test]
fn adaptive_runs_from_an_edge_centric_base_fall_back_to_dynamic_chunks() {
    // When the configured schedule is itself edge-centric, the tuner's
    // vertex-centric alternative is dynamic chunking — the run must stay
    // exact and the trace must only ever hold those two policies.
    let g = catalog_analogue();
    let p = Bfs {
        root: g.max_out_degree_vertex(),
    };
    let session = GraphSession::new(&g);
    let cfg = session.config().schedule(Schedule::EdgeCentric).adaptive(true);
    let fixed = session.run_with(
        &p,
        RunOptions::new().config(session.config().schedule(Schedule::EdgeCentric)),
    );
    let r = session.run_with(&p, RunOptions::new().config(cfg));
    assert_eq!(r.values, fixed.values);
    for d in &r.metrics.tuner_decisions {
        assert!(
            matches!(
                d.schedule,
                Schedule::EdgeCentric | Schedule::Dynamic { .. }
            ),
            "{d:?}"
        );
    }
}

#[test]
fn adaptive_and_fixed_agree_under_warm_start_and_dynamic_graphs() {
    use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
    let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 21);
    let cfg = EngineConfig::default().shards(3);
    let mut session =
        GraphSession::dynamic_with_config(DynamicGraph::with_spill_threshold(g, 1_000_000), cfg);
    let cold = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(cfg.adaptive(true)),
    );
    let mut m = MutationSet::new();
    m.insert_undirected(0, 77);
    m.insert_undirected(3, 91);
    session.apply_mutations(&m).unwrap();
    let adaptive = session.run_with(
        &ConnectedComponents,
        RunOptions::new()
            .config(cfg.adaptive(true))
            .warm_start(&cold.values),
    );
    let fixed = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(cfg).warm_start(&cold.values),
    );
    assert_eq!(adaptive.values, fixed.values);
    assert_same_trace(&fixed.metrics, &adaptive.metrics, "warm dynamic cc");
}
