//! Cross-configuration integration tests: every benchmark algorithm must
//! produce identical results under the full optimisation matrix — the
//! paper's core "transparent to the user" claim — and the virtual-testbed
//! engine must agree with the real engine everywhere.

use ipregel::algos::{reference, Bfs, ConnectedComponents, MaxValue, PageRank, Sssp};
use ipregel::combine::Strategy;
use ipregel::engine::{run, EngineConfig};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::sched::Schedule;
use ipregel::sim::SimEngine;

fn matrix() -> Vec<EngineConfig> {
    let mut cfgs = Vec::new();
    for &threads in &[1usize, 4] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[
                Schedule::Static,
                Schedule::Dynamic { chunk: 64 },
                Schedule::Guided { min_chunk: 4 },
                Schedule::EdgeCentric,
            ] {
                for &bypass in &[false, true] {
                    cfgs.push(
                        EngineConfig::default()
                            .threads(threads)
                            .layout(layout)
                            .schedule(schedule)
                            .bypass(bypass),
                    );
                }
            }
        }
    }
    cfgs
}

fn graphs() -> Vec<Csr> {
    vec![
        gen::rmat(9, 6, 0.57, 0.19, 0.19, 1),
        gen::barabasi_albert(700, 3, 2),
        gen::grid(20, 25),
        gen::disjoint_rings(4, 50),
        gen::star(300),
    ]
}

#[test]
fn pagerank_identical_across_matrix() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let want = reference::pagerank(&g, 10, 0.85);
        for cfg in matrix() {
            let got = run(&g, &PageRank::default(), cfg);
            for v in g.vertices() {
                let (a, b) = (got.values[v as usize], want[v as usize]);
                assert!(
                    (a - b).abs() < 1e-12,
                    "graph {gi} v{v}: {a} vs {b} under {cfg:?}"
                );
            }
        }
    }
}

#[test]
fn cc_identical_across_matrix() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let want = reference::connected_components(&g);
        for cfg in matrix() {
            let got = run(&g, &ConnectedComponents, cfg);
            assert_eq!(got.values, want, "graph {gi} under {cfg:?}");
        }
    }
}

#[test]
fn sssp_identical_across_matrix_and_strategies() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let p = Sssp::from_hub(&g);
        let want = reference::bfs_levels(&g, p.source);
        for cfg in matrix() {
            for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
                let got = run(&g, &p, cfg.strategy(strategy));
                assert_eq!(got.values, want, "graph {gi} {strategy:?} under {cfg:?}");
            }
        }
    }
}

#[test]
fn sim_engine_agrees_with_real_engine_everywhere() {
    let g = gen::rmat(9, 5, 0.57, 0.19, 0.19, 33);
    for cfg in matrix().into_iter().step_by(3) {
        let real = run(&g, &PageRank::default(), cfg);
        let sim = SimEngine::new(&g, &PageRank::default(), cfg).run();
        for v in g.vertices() {
            assert!(
                (real.values[v as usize] - sim.values[v as usize]).abs() < 1e-12,
                "v{v} under {cfg:?}"
            );
        }
        assert_eq!(real.metrics.num_supersteps(), sim.supersteps, "{cfg:?}");

        let p = Sssp::from_hub(&g);
        let real_s = run(&g, &p, cfg.strategy(Strategy::Hybrid));
        let sim_s = SimEngine::new(&g, &p, cfg.strategy(Strategy::Hybrid)).run();
        assert_eq!(real_s.values, sim_s.values, "{cfg:?}");
    }
}

#[test]
fn maxvalue_and_bfs_work_under_final_config() {
    let g = gen::barabasi_albert(500, 4, 9);
    let final_cfg = EngineConfig::default()
        .threads(4)
        .strategy(Strategy::Hybrid)
        .layout(Layout::Externalised)
        .schedule(Schedule::Dynamic { chunk: 64 })
        .bypass(true);
    let mv = run(&g, &MaxValue { seed: |v| (v as u64).wrapping_mul(2654435761) % 1_000_003 }, final_cfg);
    // Connected BA graph: a single component, one global max.
    let want = (0..500u32)
        .map(|v| (v as u64).wrapping_mul(2654435761) % 1_000_003)
        .max()
        .unwrap();
    assert!(mv.values.iter().all(|&x| x == want));

    let root = g.max_out_degree_vertex();
    let bfs = run(&g, &Bfs { root }, final_cfg);
    let want_levels = reference::bfs_levels(&g, root);
    for v in g.vertices() {
        let lvl = bfs.values[v as usize].level;
        let got = if lvl == u32::MAX { u64::MAX } else { lvl as u64 };
        assert_eq!(got, want_levels[v as usize], "v{v}");
    }
}

#[test]
fn message_counts_are_exact_for_push_mode() {
    // DegreeCount sends exactly one message per directed edge.
    use ipregel::algos::DegreeCount;
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 3);
    let r = run(&g, &DegreeCount, EngineConfig::default().threads(4));
    assert_eq!(r.metrics.total_messages(), g.num_edges() as u64);
}

#[test]
fn bypass_skips_inactive_work_on_sssp() {
    // Long path: frontier is O(1) per superstep, so bypass activations
    // must be linear in n while scan activations are quadratic-ish.
    let g = gen::path(2000);
    let p = Sssp { source: 0 };
    let scan = run(&g, &p, EngineConfig::default());
    let bypass = run(&g, &p, EngineConfig::default().bypass(true));
    assert_eq!(scan.values, bypass.values);
    assert!(bypass.metrics.total_activations() <= scan.metrics.total_activations());
    // The scan engine still *scans* everything; activations only count
    // computed vertices, which are identical — the savings show up in
    // virtual time instead.
    let sim_scan = SimEngine::new(&g, &p, EngineConfig::default().threads(32)).run();
    let sim_bypass = SimEngine::new(&g, &p, EngineConfig::default().threads(32).bypass(true)).run();
    assert!(
        sim_bypass.virtual_seconds < sim_scan.virtual_seconds,
        "bypass {} vs scan {}",
        sim_bypass.virtual_seconds,
        sim_scan.virtual_seconds
    );
}
