//! Cross-configuration integration tests: every benchmark algorithm must
//! produce identical results under the full optimisation matrix — the
//! paper's core "transparent to the user" claim — and the virtual-testbed
//! engine must agree with the real engine everywhere. All runs go through
//! the [`GraphSession`] API, so the matrix doubles as a soak test of the
//! session's store/bitset pooling across heterogeneous configurations.

use ipregel::algos::{
    kcore, pagerank_dangling, reference, Bfs, ConnectedComponents, DanglingPageRank, DegreeCount,
    IncrementalCc, KCore, MaxValue, PageRank, Sssp, WeightedSssp,
};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::sched::Schedule;
use ipregel::sim::SimEngine;

fn matrix() -> Vec<EngineConfig> {
    let mut cfgs = Vec::new();
    for &threads in &[1usize, 4] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[
                Schedule::Static,
                Schedule::Dynamic { chunk: 64 },
                Schedule::Guided { min_chunk: 4 },
                Schedule::EdgeCentric,
            ] {
                for &bypass in &[false, true] {
                    cfgs.push(
                        EngineConfig::default()
                            .threads(threads)
                            .layout(layout)
                            .schedule(schedule)
                            .bypass(bypass),
                    );
                }
            }
        }
    }
    cfgs
}

/// Strategy × Layout × Schedule × bypass — the full per-run switch grid
/// (strategies only matter in push mode but are exercised everywhere).
fn full_matrix() -> Vec<EngineConfig> {
    let mut cfgs = Vec::new();
    for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[
                Schedule::Static,
                Schedule::Dynamic { chunk: 32 },
                Schedule::Guided { min_chunk: 4 },
                Schedule::EdgeCentric,
            ] {
                for &bypass in &[false, true] {
                    cfgs.push(
                        EngineConfig::default()
                            .threads(4)
                            .strategy(strategy)
                            .layout(layout)
                            .schedule(schedule)
                            .bypass(bypass),
                    );
                }
            }
        }
    }
    cfgs
}

fn graphs() -> Vec<Csr> {
    vec![
        gen::rmat(9, 6, 0.57, 0.19, 0.19, 1),
        gen::barabasi_albert(700, 3, 2),
        gen::grid(20, 25),
        gen::disjoint_rings(4, 50),
        gen::star(300),
    ]
}

#[test]
fn pagerank_identical_across_matrix() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let want = reference::pagerank(&g, 10, 0.85);
        let session = GraphSession::new(&g);
        for cfg in matrix() {
            let got = session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
            for v in g.vertices() {
                let (a, b) = (got.values[v as usize], want[v as usize]);
                assert!(
                    (a - b).abs() < 1e-12,
                    "graph {gi} v{v}: {a} vs {b} under {cfg:?}"
                );
            }
        }
    }
}

#[test]
fn cc_identical_across_matrix() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let want = reference::connected_components(&g);
        let session = GraphSession::new(&g);
        for cfg in matrix() {
            let got = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
            assert_eq!(got.values, want, "graph {gi} under {cfg:?}");
        }
    }
}

#[test]
fn sssp_identical_across_matrix_and_strategies() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let p = Sssp::from_hub(&g);
        let want = reference::bfs_levels(&g, p.source);
        let session = GraphSession::new(&g);
        for cfg in matrix() {
            for strategy in [Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
                let got = session.run_with(&p, RunOptions::new().config(cfg.strategy(strategy)));
                assert_eq!(got.values, want, "graph {gi} {strategy:?} under {cfg:?}");
            }
        }
    }
}

/// The satellite matrix: *every* algorithm in `algos/` against its serial
/// reference under the full Strategy × Layout × Schedule × bypass grid,
/// all through one session per graph.
#[test]
fn all_algos_match_references_across_full_matrix() {
    let g = gen::barabasi_albert(300, 3, 14);
    let gw = gen::randomly_weighted(&g, 0.5, 4.0, 99);

    // Serial ground truths, computed once.
    let cc_want = reference::connected_components(&g);
    let pr_want = reference::pagerank(&g, 10, 0.85);
    let dpr_want = pagerank_dangling::reference(&g, 10, 0.85);
    let sssp_src = g.max_out_degree_vertex();
    let sssp_want = reference::bfs_levels(&g, sssp_src);
    let wsssp_want = reference::dijkstra(&gw, sssp_src);
    let deg_want: Vec<u64> = g.vertices().map(|v| g.in_degree(v) as u64).collect();
    let kcore_want = kcore::kcore_reference(&g, 3);
    let bfs_want = reference::bfs_levels(&g, sssp_src);
    // MaxValue converges to the per-component maximum of the seeds.
    let seed = |v: u32| (v as u64).wrapping_mul(2654435761) % 1_000_003;
    let mv_want: Vec<u64> = {
        let mut comp_max = std::collections::HashMap::new();
        for v in g.vertices() {
            let e = comp_max.entry(cc_want[v as usize]).or_insert(0u64);
            *e = (*e).max(seed(v));
        }
        g.vertices().map(|v| comp_max[&cc_want[v as usize]]).collect()
    };

    let session = GraphSession::new(&g);
    let weighted_session = GraphSession::new(&gw);
    for cfg in full_matrix() {
        let cc = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert_eq!(cc.values, cc_want, "cc under {cfg:?}");

        let pr = session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
        for v in g.vertices() {
            assert!(
                (pr.values[v as usize] - pr_want[v as usize]).abs() < 1e-12,
                "pagerank v{v} under {cfg:?}"
            );
        }

        let dpr = session.run_with(&DanglingPageRank::default(), RunOptions::new().config(cfg));
        for v in g.vertices() {
            assert!(
                (dpr.values[v as usize] - dpr_want[v as usize]).abs() < 1e-12,
                "dangling pagerank v{v} under {cfg:?}"
            );
        }

        let ss = session.run_with(&Sssp { source: sssp_src }, RunOptions::new().config(cfg));
        assert_eq!(ss.values, sssp_want, "sssp under {cfg:?}");

        let ws = weighted_session.run_with(
            &WeightedSssp { source: sssp_src },
            RunOptions::new().config(cfg),
        );
        for v in gw.vertices() {
            let (a, b) = (ws.values[v as usize], wsssp_want[v as usize]);
            assert!(
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
                "weighted sssp v{v}: {a} vs {b} under {cfg:?}"
            );
        }

        let deg = session.run_with(&DegreeCount, RunOptions::new().config(cfg));
        assert_eq!(deg.values, deg_want, "degree under {cfg:?}");

        let kc = session.run_with(&KCore { k: 3 }, RunOptions::new().config(cfg));
        let kc_alive: Vec<bool> = kc.values.iter().map(|s| s.alive).collect();
        assert_eq!(kc_alive, kcore_want, "kcore under {cfg:?}");

        let bfs = session.run_with(&Bfs { root: sssp_src }, RunOptions::new().config(cfg));
        for v in g.vertices() {
            let lvl = bfs.values[v as usize].level;
            let got = if lvl == u32::MAX { u64::MAX } else { lvl as u64 };
            assert_eq!(got, bfs_want[v as usize], "bfs v{v} under {cfg:?}");
        }

        let mv = session.run_with(&MaxValue { seed }, RunOptions::new().config(cfg));
        assert_eq!(mv.values, mv_want, "maxvalue under {cfg:?}");

        // Incremental CC: warm-start from the fixpoint, add one edge that
        // merges nothing new (same component) — labels must stay the
        // union-find answer under every configuration.
        let inc = session.run_with(
            &IncrementalCc::new(vec![0, sssp_src]),
            RunOptions::new().config(cfg).warm_start(&cc_want),
        );
        assert_eq!(inc.values, cc_want, "incremental cc under {cfg:?}");
    }
    assert!(session.runs_completed() >= 48 * 9);
}

#[test]
fn sim_engine_agrees_with_real_engine_everywhere() {
    let g = gen::rmat(9, 5, 0.57, 0.19, 0.19, 33);
    let session = GraphSession::new(&g);
    for cfg in matrix().into_iter().step_by(3) {
        let real = session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
        let sim = SimEngine::new(&g, &PageRank::default(), cfg).run();
        for v in g.vertices() {
            assert!(
                (real.values[v as usize] - sim.values[v as usize]).abs() < 1e-12,
                "v{v} under {cfg:?}"
            );
        }
        assert_eq!(real.metrics.num_supersteps(), sim.supersteps, "{cfg:?}");

        let p = Sssp::from_hub(&g);
        let real_s = session.run_with(&p, RunOptions::new().config(cfg.strategy(Strategy::Hybrid)));
        let sim_s = SimEngine::new(&g, &p, cfg.strategy(Strategy::Hybrid)).run();
        assert_eq!(real_s.values, sim_s.values, "{cfg:?}");
    }
}

#[test]
fn maxvalue_and_bfs_work_under_final_config() {
    let g = gen::barabasi_albert(500, 4, 9);
    let final_cfg = EngineConfig::default()
        .threads(4)
        .strategy(Strategy::Hybrid)
        .layout(Layout::Externalised)
        .schedule(Schedule::Dynamic { chunk: 64 })
        .bypass(true);
    let session = GraphSession::with_config(&g, final_cfg);
    let mv = session.run(&MaxValue { seed: |v| (v as u64).wrapping_mul(2654435761) % 1_000_003 });
    // Connected BA graph: a single component, one global max.
    let want = (0..500u32)
        .map(|v| (v as u64).wrapping_mul(2654435761) % 1_000_003)
        .max()
        .unwrap();
    assert!(mv.values.iter().all(|&x| x == want));

    let root = g.max_out_degree_vertex();
    let bfs = session.run(&Bfs { root });
    let want_levels = reference::bfs_levels(&g, root);
    for v in g.vertices() {
        let lvl = bfs.values[v as usize].level;
        let got = if lvl == u32::MAX { u64::MAX } else { lvl as u64 };
        assert_eq!(got, want_levels[v as usize], "v{v}");
    }
}

#[test]
fn message_counts_are_exact_for_push_mode() {
    // DegreeCount sends exactly one message per directed edge.
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 3);
    let r = GraphSession::with_config(&g, EngineConfig::default().threads(4)).run(&DegreeCount);
    assert_eq!(r.metrics.total_messages(), g.num_edges() as u64);
}

#[test]
fn bypass_skips_inactive_work_on_sssp() {
    // Long path: frontier is O(1) per superstep, so bypass activations
    // must be linear in n while scan activations are quadratic-ish.
    let g = gen::path(2000);
    let p = Sssp { source: 0 };
    let session = GraphSession::new(&g);
    let scan = session.run(&p);
    let bypass = session.run_with(
        &p,
        RunOptions::new().config(EngineConfig::default().bypass(true)),
    );
    assert_eq!(scan.values, bypass.values);
    assert!(bypass.metrics.total_activations() <= scan.metrics.total_activations());
    // The scan engine still *scans* everything; activations only count
    // computed vertices, which are identical — the savings show up in
    // virtual time instead.
    let sim_scan = SimEngine::new(&g, &p, EngineConfig::default().threads(32)).run();
    let sim_bypass = SimEngine::new(&g, &p, EngineConfig::default().threads(32).bypass(true)).run();
    assert!(
        sim_bypass.virtual_seconds < sim_scan.virtual_seconds,
        "bypass {} vs scan {}",
        sim_bypass.virtual_seconds,
        sim_scan.virtual_seconds
    );
}
