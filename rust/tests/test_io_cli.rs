//! Persistence + CLI integration tests: graph round-trips through the
//! binary and text formats, catalog caching, and the `ipregel` binary's
//! subcommands end to end (spawned as a subprocess).

use ipregel::graph::{catalog, gen, io};
use std::path::PathBuf;
use std::process::Command;

fn tmp_dir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("ipregel_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

#[test]
fn large_graph_binary_roundtrip_exact() {
    let g = gen::rmat(13, 8, 0.57, 0.19, 0.19, 77);
    let dir = tmp_dir("bin");
    let p = dir.join("g.ipg");
    io::write_binary(&g, &p).unwrap();
    let g2 = io::read_binary(&p).unwrap();
    assert_eq!(g, g2);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn text_roundtrip_preserves_edge_multiset() {
    let g = gen::barabasi_albert(400, 3, 5);
    let dir = tmp_dir("txt");
    let p = dir.join("g.txt");
    io::write_edge_list(&g, &p).unwrap();
    let g2 = io::read_edge_list(&p, false).unwrap();
    assert_eq!(g.num_edges(), g2.num_edges());
    let mut a: Vec<_> = g.edges().collect();
    let mut b: Vec<_> = g2.edges().collect();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_binary_is_rejected() {
    let g = gen::ring(100);
    let dir = tmp_dir("trunc");
    let p = dir.join("g.ipg");
    io::write_binary(&g, &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
    assert!(io::read_binary(&p).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn catalog_cache_is_deterministic_across_loads() {
    let dir = tmp_dir("cat");
    let e = &catalog::catalog_tiny()[1];
    let a = e.load_or_generate(&dir).unwrap();
    let b = e.load_or_generate(&dir).unwrap(); // cache hit
    assert_eq!(a, b);
    // Regeneration from scratch is also identical (seeded).
    std::fs::remove_file(e.cache_path(&dir)).unwrap();
    let c = e.load_or_generate(&dir).unwrap();
    assert_eq!(a, c);
    std::fs::remove_dir_all(&dir).ok();
}

// ---- CLI subprocess tests ----------------------------------------------

fn ipregel() -> Command {
    // Integration tests and the binary land in the same target profile dir.
    let mut exe = std::env::current_exe().unwrap();
    exe.pop(); // deps/
    exe.pop(); // debug|release/
    exe.push(format!("ipregel{}", std::env::consts::EXE_SUFFIX));
    assert!(
        exe.exists(),
        "binary not built at {} — cargo builds it automatically for integration tests",
        exe.display()
    );
    Command::new(exe)
}

fn run_ok(args: &[&str]) -> String {
    let out = ipregel().args(args).output().unwrap();
    assert!(
        out.status.success(),
        "ipregel {args:?} failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).to_string()
}

#[test]
fn cli_help_and_unknown_subcommand() {
    let help = run_ok(&["help"]);
    assert!(help.contains("table2"));
    let out = ipregel().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn cli_info_run_sim_on_generated_graph() {
    let dir = tmp_dir("cli");
    let dirs = dir.to_str().unwrap();

    let info = run_ok(&["info", "dblp-t", "--dir", dirs]);
    assert!(info.contains("num_vertices"));

    let run_out = run_ok(&[
        "run", "--algo", "cc", "dblp-t", "--dir", dirs, "--threads", "2", "--bypass",
    ]);
    assert!(run_out.contains("components:"), "{run_out}");

    let sim_out = run_ok(&[
        "sim", "--algo", "sssp", "dblp-t", "--dir", dirs, "--threads", "32", "--bypass",
        "--strategy", "hybrid",
    ]);
    assert!(sim_out.contains("virtual s"), "{sim_out}");

    let pr_out = run_ok(&[
        "run", "--algo", "pr", "dblp-t", "--dir", dirs, "--layout", "soa", "--schedule",
        "dynamic:64",
    ]);
    assert!(pr_out.contains("top ranks:"), "{pr_out}");

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_weighted_sssp_on_weighted_edge_list() {
    let dir = tmp_dir("wsssp");
    let p = dir.join("w.txt");
    // 0 -> 2 direct costs 10; the detour through 1 costs 3.
    std::fs::write(&p, "0 2 10.0\n0 1 1.0\n1 2 2.0\n").unwrap();
    let out = run_ok(&[
        "run", "--algo", "wsssp", p.to_str().unwrap(), "--source", "0", "--bypass",
    ]);
    assert!(out.contains("weighted-sssp"), "{out}");
    assert!(out.contains("reached 3 vertices"), "{out}");
    assert!(out.contains("eccentricity 3.000"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn weighted_binary_cache_roundtrip_via_io() {
    let base = gen::barabasi_albert(150, 3, 4);
    let g = gen::randomly_weighted(&base, 1.0, 9.0, 2);
    let dir = tmp_dir("wbin");
    let p = dir.join("w.ipg");
    io::write_binary(&g, &p).unwrap();
    let g2 = io::read_binary(&p).unwrap();
    assert_eq!(g, g2);
    assert!(g2.has_weights());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_table1_tiny() {
    let dir = tmp_dir("t1");
    let out = run_ok(&["table1", "--tiny", "--dir", dir.to_str().unwrap()]);
    assert!(out.contains("Friendster"));
    assert!(out.contains("1,806,067,135"));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cli_rejects_unknown_flags() {
    let out = ipregel()
        .args(["run", "--algo", "pr", "dblp-t", "--theads", "2"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown option"));
}

#[test]
fn cli_table2_single_bench_tiny() {
    let dir = tmp_dir("t2");
    let out = run_ok(&[
        "table2", "--tiny", "--dir", dir.to_str().unwrap(), "--bench", "sssp", "--chunk", "16",
    ]);
    assert!(out.contains("SSSP"), "{out}");
    assert!(out.contains("Hybrid combiner"), "{out}");
    assert!(out.contains("paper"), "{out}");
    std::fs::remove_dir_all(&dir).ok();
}
