//! Dynamic-graph subsystem integration tests: the bit-identity
//! contract. For random mutation batches, running a program on the
//! `DynamicGraph`'s delta-merged view must equal a cold run on a CSR
//! rebuilt from scratch over the same logical edge set — across the
//! Strategy × Layout × Schedule × Partitioning grid — and compaction
//! mid-sequence must not perturb anything.

use ipregel::algos::incremental::{
    delta_pagerank_halt, incremental_cc, incremental_pagerank, incremental_sssp, DeltaPageRank,
    IncrementalState,
};
use ipregel::algos::{reference, ConnectedComponents, PageRank, WeightedSssp};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
use ipregel::graph::{gen, Csr, GraphBuilder};
use ipregel::layout::Layout;
use ipregel::sched::Schedule;
use ipregel::util::rng::Rng;

/// Rebuild the merged view from scratch — the cold-path ground truth
/// (the same fold compaction uses).
fn rebuild(g: &Csr) -> Csr {
    g.rebuilt()
}

/// Strategy × Layout × Schedule × bypass × Partitioning — the grid the
/// acceptance criterion names. Schedules and shard counts are crossed
/// fully; 96 configurations total.
fn grid() -> Vec<EngineConfig> {
    let mut cfgs = Vec::new();
    for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[Schedule::Static, Schedule::Dynamic { chunk: 32 }] {
                for &bypass in &[false, true] {
                    for &shards in &[0usize, 3] {
                        cfgs.push(
                            EngineConfig::default()
                                .threads(4)
                                .strategy(strategy)
                                .layout(layout)
                                .schedule(schedule)
                                .bypass(bypass)
                                .shards(shards),
                        );
                    }
                }
            }
        }
    }
    // The remaining schedules at one representative point each, so all
    // four schedules appear in the grid without doubling its size.
    for &schedule in &[Schedule::Guided { min_chunk: 4 }, Schedule::EdgeCentric] {
        for &shards in &[0usize, 3] {
            cfgs.push(
                EngineConfig::default()
                    .threads(4)
                    .schedule(schedule)
                    .bypass(true)
                    .shards(shards),
            );
        }
    }
    cfgs
}

fn random_batch(rng: &mut Rng, g: &Csr, weighted: bool) -> MutationSet {
    let n = g.num_vertices() as u64;
    let mut m = MutationSet::new();
    for _ in 0..6 {
        let (s, d) = (rng.below(n) as u32, rng.below(n) as u32);
        if s == d {
            continue;
        }
        if weighted {
            let w = 0.25 + (rng.below(800) as f64) / 200.0;
            m.insert_weighted(s, d, w);
            m.insert_weighted(d, s, w);
        } else {
            m.insert_undirected(s, d);
        }
    }
    // A couple of real deletions, symmetric to keep CC's assumption.
    for _ in 0..2 {
        let v = (0..g.num_vertices() as u32)
            .find(|&v| g.out_degree(v) > 0)
            .expect("graph has edges");
        let d = g.out_neighbors(v)[rng.below(g.out_degree(v) as u64) as usize];
        m.delete_undirected(v, d);
    }
    m
}

#[test]
fn bit_identity_across_the_grid_unweighted() {
    let base = gen::rmat(7, 4, 0.57, 0.19, 0.19, 3);
    let mut dg = DynamicGraph::with_spill_threshold(base, 1_000_000);
    let mut rng = Rng::new(0xD15C);
    for _ in 0..2 {
        let m = random_batch(&mut rng, dg.graph(), false);
        dg.apply(&m);
    }
    let g = dg.graph();
    assert!(g.has_overlay(), "the point is to run over live deltas");
    let cold = rebuild(g);
    let dyn_session = GraphSession::new(g);
    let cold_session = GraphSession::new(&cold);
    for cfg in grid() {
        let a = dyn_session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
        let b = cold_session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
        assert_eq!(a.values, b.values, "pagerank under {cfg:?}");
        assert_eq!(
            a.metrics.num_supersteps(),
            b.metrics.num_supersteps(),
            "pagerank supersteps under {cfg:?}"
        );

        let c = dyn_session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        let d = cold_session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        assert_eq!(c.values, d.values, "cc under {cfg:?}");
        assert_eq!(
            c.metrics.total_messages(),
            d.metrics.total_messages(),
            "cc message parity under {cfg:?}"
        );
    }
}

#[test]
fn bit_identity_across_the_grid_weighted() {
    let base = gen::randomly_weighted(&gen::rmat(7, 4, 0.57, 0.19, 0.19, 9), 0.5, 4.0, 11);
    let source = base.max_out_degree_vertex();
    let mut dg = DynamicGraph::with_spill_threshold(base, 1_000_000);
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..2 {
        let m = random_batch(&mut rng, dg.graph(), true);
        dg.apply(&m);
    }
    let g = dg.graph();
    assert!(g.has_overlay());
    assert!(g.has_weights());
    let cold = rebuild(g);
    let dyn_session = GraphSession::new(g);
    let cold_session = GraphSession::new(&cold);
    let p = WeightedSssp { source };
    for cfg in grid() {
        let a = dyn_session.run_with(&p, RunOptions::new().config(cfg));
        let b = cold_session.run_with(&p, RunOptions::new().config(cfg));
        assert_eq!(a.values, b.values, "weighted sssp under {cfg:?}");
    }
    // And the merged view agrees with the serial reference.
    let dij = reference::dijkstra(&cold, source);
    let got = dyn_session.run(&p);
    for v in g.vertices() {
        let (a, b) = (got.values[v as usize], dij[v as usize]);
        assert!(
            (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
            "v{v}: {a} vs {b}"
        );
    }
}

#[test]
fn compaction_mid_sequence_preserves_results() {
    // Spill threshold low enough that the batch stream compacts several
    // times; after every batch the dynamic run must equal a cold run on
    // the rebuild, whether or not this batch compacted.
    let base = gen::rmat(7, 4, 0.57, 0.19, 0.19, 21);
    let mut session = GraphSession::dynamic_with_config(
        DynamicGraph::with_spill_threshold(base, 20),
        EngineConfig::default().threads(2).shards(3),
    );
    let mut rng = Rng::new(7);
    let mut compactions_seen = 0u64;
    for round in 0..6 {
        let m = random_batch(&mut rng, session.graph(), false);
        let receipt = session.apply_mutations(&m).unwrap();
        if receipt.compacted {
            compactions_seen += 1;
        }
        let cold = rebuild(session.graph());
        let a = session.run(&ConnectedComponents);
        let b = GraphSession::with_config(&cold, session.config()).run(&ConnectedComponents);
        assert_eq!(a.values, b.values, "round {round} (compacted: {})", receipt.compacted);
        assert_eq!(a.values, reference::connected_components(&cold), "round {round}");
    }
    assert!(
        compactions_seen >= 1,
        "threshold 20 must compact at least once in 6 batches"
    );
    assert_eq!(
        session.dynamic_graph().unwrap().stats().compactions,
        compactions_seen
    );
}

#[test]
fn incremental_recompute_chain_stays_exact_over_many_epochs() {
    // The service loop: one dynamic session, a stream of insert-only
    // batches, incremental CC and SSSP chained epoch to epoch — always
    // equal to cold answers, always cheaper than restarting CC cold.
    let base = {
        let mut gb = GraphBuilder::new(120).symmetric(true);
        for c in 0..4 {
            for v in 0..30u32 {
                gb.push_edge(c * 30 + v, c * 30 + (v + 1) % 30);
            }
        }
        gb.build()
    };
    let mut session = GraphSession::dynamic_with_config(
        DynamicGraph::with_spill_threshold(base, 1_000_000),
        EngineConfig::default(),
    );
    let cold = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(session.config().bypass(true)),
    );
    let mut cc_state = IncrementalState::new(cold.values, 0);
    let mut inc_activations = 0u64;
    let mut cold_activations = 0u64;
    for (a, b) in [(5u32, 40u32), (70, 100), (10, 75)] {
        let mut m = MutationSet::new();
        m.insert_undirected(a, b);
        let receipt = session.apply_mutations(&m).unwrap();
        let (inc, next) = incremental_cc(&session, &cc_state, &receipt).unwrap();
        let want = reference::connected_components(session.graph());
        assert_eq!(next.values, want, "after {a}-{b}");
        let cold = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(session.config().bypass(true)),
        );
        inc_activations += inc.total_activations();
        cold_activations += cold.metrics.total_activations();
        cc_state = next;
    }
    assert_eq!(cc_state.epoch, 3);
    assert!(
        inc_activations < cold_activations,
        "incremental {inc_activations} vs cold {cold_activations}"
    );
}

#[test]
fn incremental_sssp_and_pagerank_agree_with_cold_after_mutations() {
    let base = gen::randomly_weighted(&gen::rmat(7, 3, 0.57, 0.19, 0.19, 41), 0.5, 3.0, 5);
    let source = base.max_out_degree_vertex();
    let mut session = GraphSession::dynamic_with_config(
        DynamicGraph::with_spill_threshold(base, 1_000_000),
        EngineConfig::default(),
    );
    // SSSP chain (insert-only).
    let cold = session.run_with(
        &WeightedSssp { source },
        RunOptions::new().config(session.config().bypass(true)),
    );
    let mut ss_state = IncrementalState::new(cold.values, 0);
    // PageRank chain (any mutations).
    let p = DeltaPageRank::default();
    let pr_cold = session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
    let mut pr_state = IncrementalState::new(pr_cold.values, 0);

    let mut rng = Rng::new(99);
    for round in 0..3 {
        let n = session.graph().num_vertices() as u64;
        let mut m = MutationSet::new();
        for _ in 0..4 {
            let (s, d) = (rng.below(n) as u32, rng.below(n) as u32);
            if s != d {
                let w = 0.25 + (rng.below(400) as f64) / 100.0;
                m.insert_weighted(s, d, w);
            }
        }
        let receipt = session.apply_mutations(&m).unwrap();

        let (_ss_metrics, ss_next) = incremental_sssp(&session, &ss_state, &receipt).unwrap();
        let want = reference::dijkstra(session.graph(), source);
        for v in session.graph().vertices() {
            let (a, b) = (ss_next.values[v as usize], want[v as usize]);
            assert!(
                (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
                "round {round} v{v}: {a} vs {b}"
            );
        }
        ss_state = ss_next;

        let (pr_metrics, pr_next) =
            incremental_pagerank(&session, &pr_state, &receipt, &p).unwrap();
        let pr_cold = session.run_with(&p, RunOptions::new().halt(delta_pagerank_halt(&p)));
        for v in session.graph().vertices() {
            let (a, b) = (pr_next.values[v as usize], pr_cold.values[v as usize]);
            assert!((a - b).abs() < 1e-7, "round {round} v{v}: {a} vs {b}");
        }
        assert!(
            pr_metrics.num_supersteps() <= pr_cold.metrics.num_supersteps(),
            "warm PageRank must not take more supersteps than cold"
        );
        pr_state = pr_next;
    }
}

#[test]
fn deletions_flow_through_engine_and_metrics() {
    let base = gen::grid(8, 8);
    let edges_before = base.num_edges();
    let mut session = GraphSession::dynamic_with_config(
        DynamicGraph::with_spill_threshold(base, 1_000_000),
        EngineConfig::default().shards(2),
    );
    let mut m = MutationSet::new();
    m.delete_undirected(0, 1);
    let receipt = session.apply_mutations(&m).unwrap();
    assert_eq!(receipt.removed.len(), 2, "one undirected edge = two instances");
    assert_eq!(session.graph().num_edges(), edges_before - 2);
    let cold = rebuild(session.graph());
    let a = session.run(&ConnectedComponents);
    let b = GraphSession::with_config(&cold, session.config()).run(&ConnectedComponents);
    assert_eq!(a.values, b.values);
    assert_eq!(a.metrics.graph_epoch, 1);
    assert!(a.metrics.delta_edges > 0);
    assert!(a.metrics.delta_occupancy > 0.0);
}
