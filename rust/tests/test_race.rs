//! Race-checker acceptance suite (`cargo test --features race-check`).
//!
//! Three halves:
//!   1. **Seeded races are caught** — deliberately violating the engine's
//!      phase discipline (two unsynchronised writers to one slot/cell in
//!      the same phase) must panic with a shadow-state diagnostic. A
//!      checker that never fires checks nothing.
//!   2. **Legal patterns stay silent** — lock-synchronised writers and
//!      phase-separated accesses must pass.
//!   3. **The engine itself is clean** — a parity grid (Strategy × Layout
//!      × Schedule × partitioning, plus a log-plane program) runs under
//!      full instrumentation and still matches the serial references.
//!
//! Every test serialises on one mutex: the phase counter is global, so a
//! concurrently running parallel region would bump it between a seeded
//! test's two writes and hide the conflict. (False positives are immune
//! to interleaving — phases are monotonic, so an extra bump can only
//! *separate* accesses, never merge them — but seeded *detection* needs
//! a quiet phase.)

#![cfg(feature = "race-check")]

use ipregel::algos::{reference, ConnectedComponents, Lpa, PageRank, Sssp};
use ipregel::combine::{MsgSlot, SpinLock, Strategy};
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::gen;
use ipregel::layout::{Layout, SyncCell};
use ipregel::sched::Schedule;
use ipregel::util::shadow;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread;

static PHASE_QUIET: Mutex<()> = Mutex::new(());

fn quiet() -> std::sync::MutexGuard<'static, ()> {
    // A previous test's failed assert may have poisoned the mutex; the
    // shadow state itself is still valid (each test opens with its own
    // sync_point), so keep going.
    PHASE_QUIET.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Run `f` on a fresh thread and report whether it panicked. Seeded
/// violations fire inside the offending thread, so `join` carries them.
fn spawned_panics<F: FnOnce() + Send + 'static>(f: F) -> bool {
    thread::spawn(f).join().is_err()
}

#[test]
fn seeded_slot_double_write_is_detected() {
    let _g = quiet();
    shadow::sync_point();
    let slot = Arc::new(MsgSlot::<u64>::new());
    let (s1, s2) = (Arc::clone(&slot), Arc::clone(&slot));
    // Two threads write the same slot without the lock, in one phase:
    // exactly the lost-update shape the hybrid combiner must never allow.
    assert!(!spawned_panics(move || s1.store_first(1)), "first write is legal");
    assert!(
        spawned_panics(move || s2.store_first(2)),
        "second unsynchronised write in the same phase must panic"
    );
}

#[test]
fn seeded_slot_write_read_overlap_is_detected() {
    let _g = quiet();
    shadow::sync_point();
    let slot = Arc::new(MsgSlot::<u64>::new());
    let (s1, s2) = (Arc::clone(&slot), Arc::clone(&slot));
    assert!(!spawned_panics(move || s1.store_first(7)));
    assert!(
        spawned_panics(move || {
            s2.peek();
        }),
        "unsynchronised read overlapping a same-phase write must panic"
    );
}

#[test]
fn seeded_cell_double_write_is_detected() {
    let _g = quiet();
    shadow::sync_point();
    let cell = Arc::new(SyncCell::new(0u64));
    let (c1, c2) = (Arc::clone(&cell), Arc::clone(&cell));
    assert!(!spawned_panics(move || *c1.get_mut() = 1));
    assert!(
        spawned_panics(move || *c2.get_mut() = 2),
        "two same-phase owners of one vertex cell must panic"
    );
}

#[test]
fn seeded_deque_double_execution_is_detected() {
    let _g = quiet();
    shadow::sync_point();
    // The only way the Chase-Lev protocol can fail is an item claimed
    // twice in one phase; its shadow cell must turn that into a panic.
    let set = Arc::new(ipregel::sched::StealSet::new(4, 2, None));
    let (s1, s2) = (Arc::clone(&set), Arc::clone(&set));
    assert!(
        !spawned_panics(move || s1.mark_execute(1)),
        "first execution is legal"
    );
    assert!(
        spawned_panics(move || s2.mark_execute(1)),
        "same-phase double execution of one item must panic"
    );
}

#[test]
fn steal_handoff_is_legal() {
    let _g = quiet();
    shadow::sync_point();
    // Owner drains its own deque, a thief then claims the peer's items:
    // every index executes exactly once, so the checker must stay silent
    // even though two threads touch the set in the same phase.
    let set = Arc::new(ipregel::sched::StealSet::new(8, 2, None)); // w0: 0..4, w1: 4..8
    let a = Arc::clone(&set);
    assert!(!spawned_panics(move || {
        while let Some(i) = a.take(0) {
            a.mark_execute(i);
        }
    }));
    let b = Arc::clone(&set);
    assert!(
        !spawned_panics(move || {
            while let Some(i) = b.steal_from(0, 1) {
                b.mark_execute(i);
            }
        }),
        "stolen items are exclusively owned — a handoff is not a race"
    );
    assert!(set.steals_total() > 0, "the thief did steal");
}

#[test]
fn instrumented_steal_execute_is_race_free() {
    let _g = quiet();
    shadow::sync_point();
    // Real contention: skewed weights force three near-empty workers to
    // steal from the loaded one, with every execution shadow-tracked.
    use std::sync::atomic::{AtomicU64, Ordering};
    let n = 8192usize;
    let mut w = vec![0u64; n];
    for x in w.iter_mut().take(n / 8) {
        *x = 1000;
    }
    let counts: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
    let steals = ipregel::sched::steal_execute(4, n, Some(&w), 2, n, |_t, i| {
        counts[i].fetch_add(1, Ordering::Relaxed);
    });
    for (i, c) in counts.iter().enumerate() {
        assert_eq!(c.load(Ordering::Relaxed), 1, "item {i} executed once");
    }
    assert!(steals > 0, "the skew forced at least one steal");
}

#[test]
fn lock_synchronised_writers_are_legal() {
    let _g = quiet();
    shadow::sync_point();
    let slot = Arc::new(MsgSlot::<u64>::new());
    // Same phase, different threads — but both hold the slot's lock, the
    // combiner's Lock-strategy shape. Must stay silent.
    for v in [1u64, 2] {
        let s = Arc::clone(&slot);
        let ok = thread::spawn(move || s.lock().with(|| s.store_msg(v))).join();
        assert!(ok.is_ok(), "locked writers in one phase are the Lock strategy");
    }
}

#[test]
fn phase_separated_writers_are_legal() {
    let _g = quiet();
    shadow::sync_point();
    let slot = Arc::new(MsgSlot::<u64>::new());
    for v in [1u64, 2] {
        let s = Arc::clone(&slot);
        assert!(!spawned_panics(move || s.store_first(v)));
        // The barrier between supersteps, in miniature.
        shadow::sync_point();
    }
}

#[test]
fn recursive_lock_acquire_panics() {
    let _g = quiet();
    let lock = SpinLock::new();
    lock.acquire();
    let second = catch_unwind(AssertUnwindSafe(|| lock.acquire()));
    assert!(second.is_err(), "re-acquiring a held SpinLock would deadlock");
    lock.release();
}

#[test]
fn release_by_non_owner_panics() {
    let _g = quiet();
    let lock = Arc::new(SpinLock::new());
    let l = Arc::clone(&lock);
    thread::spawn(move || l.acquire()).join().unwrap();
    // The owner exited without releasing; we never acquired it.
    let stolen = catch_unwind(AssertUnwindSafe(|| lock.release()));
    assert!(stolen.is_err(), "releasing a lock this thread never took must panic");
}

/// The real acceptance bar: the full engine, instrumented end to end
/// (slots, cells, locks, pools, log-plane segments), neither trips the
/// checker nor changes a single answer.
#[test]
fn parity_grid_is_race_free_and_correct() {
    let _g = quiet();
    let g = gen::rmat(8, 6, 0.57, 0.19, 0.19, 1);
    let pr_want = reference::pagerank(&g, 10, 0.85);
    let cc_want = reference::connected_components(&g);
    let sssp = Sssp::from_hub(&g);
    let sssp_want = reference::bfs_levels(&g, sssp.source);

    let session = GraphSession::new(&g);
    for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[Schedule::Static, Schedule::Dynamic { chunk: 32 }] {
                for &shards in &[0usize, 4] {
                    let cfg = EngineConfig::default()
                        .threads(4)
                        .strategy(strategy)
                        .layout(layout)
                        .schedule(schedule)
                        .shards(shards);
                    let cc =
                        session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
                    assert_eq!(cc.values, cc_want, "cc under {cfg:?}");
                    let pr =
                        session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
                    for v in g.vertices() {
                        assert!(
                            (pr.values[v as usize] - pr_want[v as usize]).abs() < 1e-12,
                            "pagerank v{v} under {cfg:?}"
                        );
                    }
                    let sp = session.run_with(&sssp, RunOptions::new().config(cfg));
                    assert_eq!(sp.values, sssp_want, "sssp under {cfg:?}");
                }
            }
        }
    }

    // Work-stealing dispatch under full instrumentation: whole shards
    // may move between workers; per-item exclusivity must hold and the
    // answers must not move.
    let steal_cfg = EngineConfig::default()
        .threads(4)
        .shards(4)
        .bypass(true)
        .steal(true);
    let sp = session.run_with(&sssp, RunOptions::new().config(steal_cfg));
    assert_eq!(sp.values, sssp_want, "sssp under stealing");

    // Log-plane coverage: Lpa routes full message multisets through
    // MessageLog segments (SyncCell-backed, so fully instrumented).
    let lpa_want = reference::lpa(&g, 3);
    let lpa = session.run_with(
        &Lpa { rounds: 3 },
        RunOptions::new().config(EngineConfig::default().threads(4)),
    );
    assert_eq!(lpa.values, lpa_want, "lpa under race-check");
}
