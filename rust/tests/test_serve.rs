//! Serving-layer integration tests: the multi-tenancy contract.
//!
//! Four families, matching the acceptance criteria:
//!
//! 1. **Bit-identity** — N concurrent served queries (with a whole-graph
//!    batch run contending at the gate) return exactly what solo runs
//!    over the same graph return: values *and* per-superstep
//!    (active, messages) traces, across flat/sharded × adaptive on/off.
//!    The serving layer is a front-end; it never perturbs the engine.
//! 2. **Budget isolation** — a query that exhausts its token or
//!    superstep budget halts with its own distinct [`HaltReason`] and
//!    hands every pooled resource back; its neighbours are unaffected.
//! 3. **Snapshot isolation** — a reader pinned to an epoch sees exactly
//!    that epoch's graph while (and after) a writer publishes mutations;
//!    the writer never waits for the pin.
//! 4. **Pool sharing** — concurrent same-shaped queries provably share
//!    warm vertex stores through the session's multi-checkout pools.

use ipregel::algos::query::{EgoNetBfs, PointSssp};
use ipregel::algos::{ConnectedComponents, PageRank};
use ipregel::engine::{EngineConfig, GraphSession};
use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
use ipregel::graph::gen;
use ipregel::metrics::{HaltReason, RunMetrics};
use ipregel::serve::{AdmissionController, QueryBudget, QueryServer, QuerySpec};
use std::sync::Mutex;

/// The per-superstep trace the bit-identity contract covers: semantic
/// counts only (wall-clock fields are obviously run-specific).
fn step_trace(m: &RunMetrics) -> Vec<(usize, u64)> {
    m.supersteps
        .iter()
        .map(|s| (s.active_vertices, s.messages))
        .collect()
}

#[test]
fn concurrent_queries_are_bit_identical_to_solo_runs() {
    let base = gen::rmat(8, 4, 0.57, 0.19, 0.19, 23);
    let solo_graph = base.rebuilt();
    let roots: [u32; 4] = [0, 7, 99, 148];
    for &shards in &[0usize, 3] {
        for &adaptive in &[false, true] {
            let cfg = EngineConfig::default()
                .threads(3)
                .shards(shards)
                .adaptive(adaptive);
            let ctx = format!("shards {shards} adaptive {adaptive}");

            // Solo ground truth: one quiet session, one run per query.
            let solo = GraphSession::with_config(&solo_graph, cfg);
            let expect_ego: Vec<_> = roots
                .iter()
                .map(|&root| {
                    let out = solo.run(&EgoNetBfs { root, radius: 2 });
                    (out.values, step_trace(&out.metrics))
                })
                .collect();
            let expect_sssp: Vec<_> = roots
                .iter()
                .map(|&source| {
                    let out = solo.run(&PointSssp {
                        source,
                        cutoff: 3.0,
                    });
                    (out.values, step_trace(&out.metrics))
                })
                .collect();
            let expect_cc = solo.run(&ConnectedComponents);

            // Served: all small queries in flight at once, plus a
            // whole-graph batch run contending at the admission gate.
            let server =
                QueryServer::with_config(base.rebuilt(), cfg, AdmissionController::new(8));
            let got_ego: Mutex<Vec<(usize, Vec<u64>, Vec<(usize, u64)>)>> =
                Mutex::new(Vec::new());
            let got_sssp: Mutex<Vec<(usize, Vec<f64>, Vec<(usize, u64)>)>> =
                Mutex::new(Vec::new());
            std::thread::scope(|s| {
                let server = &server;
                s.spawn(move || {
                    let r = server
                        .execute(
                            &PageRank {
                                iterations: 5,
                                damping: 0.85,
                            },
                            &QuerySpec::batch().config(cfg),
                        )
                        .unwrap();
                    assert!(r.metrics.num_supersteps() > 0);
                });
                for (i, &root) in roots.iter().enumerate() {
                    let got_ego = &got_ego;
                    s.spawn(move || {
                        let r = server
                            .execute(
                                &EgoNetBfs { root, radius: 2 },
                                &QuerySpec::interactive().config(cfg),
                            )
                            .unwrap();
                        got_ego
                            .lock()
                            .unwrap()
                            .push((i, r.values, step_trace(&r.metrics)));
                    });
                    let got_sssp = &got_sssp;
                    s.spawn(move || {
                        let r = server
                            .execute(
                                &PointSssp {
                                    source: root,
                                    cutoff: 3.0,
                                },
                                &QuerySpec::interactive().config(cfg),
                            )
                            .unwrap();
                        got_sssp
                            .lock()
                            .unwrap()
                            .push((i, r.values, step_trace(&r.metrics)));
                    });
                }
            });
            for (i, values, trace) in got_ego.into_inner().unwrap() {
                assert_eq!(values, expect_ego[i].0, "ego-net values, root {i} ({ctx})");
                assert_eq!(trace, expect_ego[i].1, "ego-net trace, root {i} ({ctx})");
            }
            for (i, values, trace) in got_sssp.into_inner().unwrap() {
                assert_eq!(values, expect_sssp[i].0, "point-sssp values, root {i} ({ctx})");
                assert_eq!(trace, expect_sssp[i].1, "point-sssp trace, root {i} ({ctx})");
            }
            // And a served whole-graph run matches its solo twin too.
            let served_cc = server
                .execute(&ConnectedComponents, &QuerySpec::batch().config(cfg))
                .unwrap();
            assert_eq!(served_cc.values, expect_cc.values, "cc values ({ctx})");
            assert_eq!(
                step_trace(&served_cc.metrics),
                step_trace(&expect_cc.metrics),
                "cc trace ({ctx})"
            );
        }
    }
}

#[test]
fn budget_exhaustion_is_isolated_per_query() {
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 5);
    let solo = GraphSession::new(&g).run(&ConnectedComponents);
    let server = QueryServer::new(g.rebuilt());

    let starved = server
        .execute(
            &ConnectedComponents,
            &QuerySpec::interactive().budget(QueryBudget::tokens(1)),
        )
        .unwrap();
    assert_eq!(starved.query.halt_reason, HaltReason::BudgetExhausted);
    assert!(
        starved.metrics.num_supersteps() < solo.metrics.num_supersteps(),
        "the token budget actually cut the run short"
    );

    let capped = server
        .execute(
            &ConnectedComponents,
            &QuerySpec::interactive().budget(QueryBudget::supersteps(1)),
        )
        .unwrap();
    assert_eq!(
        capped.query.halt_reason,
        HaltReason::SuperstepCap,
        "each budget axis surfaces its own distinct reason"
    );

    // The pool is not poisoned: an unbounded rerun on the same server
    // converges to the solo answer, on a store a budgeted run handed back.
    let full = server
        .execute(&ConnectedComponents, &QuerySpec::interactive())
        .unwrap();
    assert_eq!(full.query.halt_reason, HaltReason::Quiescence);
    assert_eq!(full.values, solo.values);
    assert!(full.query.store_reused, "exhausted runs returned their stores");
    assert_eq!(server.queries_completed(), 3);
}

#[test]
fn pinned_readers_see_the_premutation_snapshot() {
    let pre = gen::path(8);
    let probe = EgoNetBfs { root: 0, radius: 8 };

    // Ground truth on both sides of the mutation, from scratch sessions.
    let pre_expect = GraphSession::new(&pre).run(&probe).values;
    let mut m = MutationSet::new();
    m.insert_undirected(0, 7);
    let mut shadow = DynamicGraph::new(pre.rebuilt());
    shadow.apply(&m);
    let post_graph = shadow.graph().rebuilt();
    let post_expect = GraphSession::new(&post_graph).run(&probe).values;
    assert_ne!(pre_expect, post_expect, "the mutation must be observable");

    let server = QueryServer::new(pre.rebuilt());
    let pinned = server.pin_current();
    assert_eq!(server.pinned_readers(0), 1);

    // The writer publishes while the pinned reader is mid-flight; the
    // reader's answer is the pinned epoch's regardless of who wins.
    std::thread::scope(|s| {
        let (server, pinned, probe) = (&server, &pinned, &probe);
        let reader = s.spawn(move || {
            server
                .execute_on(pinned, probe, &QuerySpec::interactive())
                .unwrap()
        });
        let receipt = server.apply_mutations(&m);
        assert_eq!(receipt.epoch, 1, "writer published without blocking");
        let old = reader.join().unwrap();
        assert_eq!(old.values, pre_expect, "pinned read = pre-mutation snapshot");
        assert_eq!(old.query.epoch, 0);
    });

    // Fresh queries see the new epoch; the pin still time-travels.
    assert_eq!(server.epoch(), 1);
    let fresh = server
        .execute(&probe, &QuerySpec::interactive())
        .unwrap();
    assert_eq!(fresh.values, post_expect);
    assert_eq!(fresh.query.epoch, 1);
    let old_again = server
        .execute_on(&pinned, &probe, &QuerySpec::interactive())
        .unwrap();
    assert_eq!(old_again.values, pre_expect);
    assert_eq!(old_again.query.epoch, 0);
    assert_eq!(server.oldest_pinned(), Some(0));
    drop(pinned);
    assert_eq!(server.oldest_pinned(), None, "dropping the pin retires the epoch");
}

#[test]
fn concurrent_queries_share_pooled_stores() {
    let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 13);
    let cfg = EngineConfig::default().threads(2);
    let solo = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
    let expect = &solo.values;

    // A gate of 2 bounds live stores at 2, so at least 6 of the 8
    // checkouts below must be served warm from the pool.
    let server = QueryServer::with_config(g.rebuilt(), cfg, AdmissionController::new(2));
    std::thread::scope(|s| {
        for _ in 0..8 {
            let server = &server;
            s.spawn(move || {
                let r = server
                    .execute(&ConnectedComponents, &QuerySpec::interactive())
                    .unwrap();
                assert_eq!(&r.values, expect);
            });
        }
    });
    assert_eq!(server.queries_completed(), 8);
    assert_eq!(server.runs_completed(), 8);
    let pool = server.pool_stats();
    assert_eq!(pool.store_checkouts, 8);
    assert!(
        pool.store_hits >= 6,
        "shared stores: only {} of {} checkouts hit the pool",
        pool.store_hits,
        pool.store_checkouts
    );
}
