//! Partitioned-substrate integration tests.
//!
//! The contract under test: sharded execution is **bit-identical** to
//! the flat engine — same final values, same superstep count, same
//! per-superstep active counts and message totals — for every algorithm
//! in the parity matrix, across the Strategy × Layout × Schedule ×
//! bypass grid; and the partition itself satisfies its structural
//! invariants (every edge interior xor cross, owner map a consistent
//! cover, message split exactly covering the message total).

use ipregel::algos::{
    reference, Bfs, ConnectedComponents, DegreeCount, MaxValue, PageRank, Sssp, WeightedSssp,
};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession, Partitioning, RunOptions};
use ipregel::graph::csr::Csr;
use ipregel::graph::gen;
use ipregel::graph::partition::PartitionPlan;
use ipregel::layout::Layout;
use ipregel::metrics::{RunMetrics, ScheduleFallback};
use ipregel::sched::Schedule;
use ipregel::util::quick;

fn graphs() -> Vec<Csr> {
    vec![
        gen::rmat(8, 5, 0.57, 0.19, 0.19, 2),
        gen::grid(15, 16),
        gen::star(200),
        gen::disjoint_rings(3, 40),
    ]
}

/// Strategy × Layout × Schedule × bypass, trimmed to stay fast: every
/// switch appears with every other at least once.
fn grid() -> Vec<EngineConfig> {
    let mut cfgs = Vec::new();
    for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[
                Schedule::Static,
                Schedule::Dynamic { chunk: 16 },
                Schedule::Guided { min_chunk: 2 },
                Schedule::EdgeCentric,
            ] {
                for &bypass in &[false, true] {
                    cfgs.push(
                        EngineConfig::default()
                            .threads(4)
                            .strategy(strategy)
                            .layout(layout)
                            .schedule(schedule)
                            .bypass(bypass),
                    );
                }
            }
        }
    }
    cfgs
}

/// Superstep traces must agree step for step: active counts and message
/// totals (times of course differ).
fn assert_same_trace(flat: &RunMetrics, sharded: &RunMetrics, what: &str) {
    assert_eq!(
        flat.num_supersteps(),
        sharded.num_supersteps(),
        "{what}: superstep count"
    );
    for (i, (a, b)) in flat
        .supersteps
        .iter()
        .zip(sharded.supersteps.iter())
        .enumerate()
    {
        assert_eq!(
            a.active_vertices, b.active_vertices,
            "{what}: active count at superstep {i}"
        );
        assert_eq!(a.messages, b.messages, "{what}: messages at superstep {i}");
    }
    assert_eq!(flat.halt_reason, sharded.halt_reason, "{what}: halt reason");
}

#[test]
fn sharded_bit_identical_to_flat_across_grid() {
    for (gi, g) in graphs().into_iter().enumerate() {
        let session = GraphSession::new(&g);
        for cfg in grid() {
            let flat_pr = session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
            let flat_ss =
                session.run_with(&Sssp::from_hub(&g), RunOptions::new().config(cfg));
            for shards in [1usize, 3, 8] {
                let scfg = cfg.shards(shards);
                let pr = session.run_with(&PageRank::default(), RunOptions::new().config(scfg));
                // Bitwise equality, not tolerance: pull combines fold in
                // identical in-neighbour order on both substrates.
                assert_eq!(
                    pr.values, flat_pr.values,
                    "graph {gi} pagerank {shards} shards under {cfg:?}"
                );
                assert_same_trace(
                    &flat_pr.metrics,
                    &pr.metrics,
                    &format!("graph {gi} pagerank {shards} shards under {cfg:?}"),
                );

                let ss = session.run_with(&Sssp::from_hub(&g), RunOptions::new().config(scfg));
                assert_eq!(
                    ss.values, flat_ss.values,
                    "graph {gi} sssp {shards} shards under {cfg:?}"
                );
                assert_same_trace(
                    &flat_ss.metrics,
                    &ss.metrics,
                    &format!("graph {gi} sssp {shards} shards under {cfg:?}"),
                );
            }
        }
    }
}

#[test]
fn all_parity_algorithms_match_under_sharding() {
    let g = gen::barabasi_albert(400, 3, 8);
    let gw = gen::randomly_weighted(&g, 0.5, 4.0, 17);
    let session = GraphSession::new(&g);
    let weighted_session = GraphSession::new(&gw);
    let src = g.max_out_degree_vertex();
    let seed = |v: u32| (v as u64).wrapping_mul(2654435761) % 1_000_003;

    let cc_want = reference::connected_components(&g);
    let pr_want = reference::pagerank(&g, 10, 0.85);
    let bfs_want = reference::bfs_levels(&g, src);
    let wsssp_want = reference::dijkstra(&gw, src);
    let deg_want: Vec<u64> = g.vertices().map(|v| g.in_degree(v) as u64).collect();

    for shards in [2usize, 6] {
        for bypass in [false, true] {
            let cfg = EngineConfig::default()
                .threads(4)
                .strategy(Strategy::Hybrid)
                .bypass(bypass)
                .shards(shards);

            let cc = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
            assert_eq!(cc.values, cc_want, "cc {shards} shards bypass={bypass}");

            let pr = session.run_with(&PageRank::default(), RunOptions::new().config(cfg));
            for v in g.vertices() {
                assert!(
                    (pr.values[v as usize] - pr_want[v as usize]).abs() < 1e-12,
                    "pagerank v{v} {shards} shards bypass={bypass}"
                );
            }

            let bfs = session.run_with(&Bfs { root: src }, RunOptions::new().config(cfg));
            for v in g.vertices() {
                let lvl = bfs.values[v as usize].level;
                let got = if lvl == u32::MAX { u64::MAX } else { lvl as u64 };
                assert_eq!(got, bfs_want[v as usize], "bfs v{v} {shards} shards");
            }

            let ws = weighted_session.run_with(
                &WeightedSssp { source: src },
                RunOptions::new().config(cfg),
            );
            for v in gw.vertices() {
                let (a, b) = (ws.values[v as usize], wsssp_want[v as usize]);
                assert!(
                    (a.is_infinite() && b.is_infinite()) || (a - b).abs() < 1e-9,
                    "weighted sssp v{v} {shards} shards"
                );
            }

            let deg = session.run_with(&DegreeCount, RunOptions::new().config(cfg));
            assert_eq!(deg.values, deg_want, "degree {shards} shards");

            let mv = session.run_with(&MaxValue { seed }, RunOptions::new().config(cfg));
            let flat_mv = session.run(&MaxValue { seed });
            assert_eq!(mv.values, flat_mv.values, "maxvalue {shards} shards");
        }
    }
}

#[test]
fn prop_partition_invariants_and_parity_on_random_graphs() {
    quick::check("sharded parity", |rng| {
        let scale = 5 + rng.below(3) as u32;
        let g = gen::rmat(scale, 4, 0.5, 0.2, 0.2, rng.below(10_000));
        let shards = 1 + rng.below(7) as usize;

        // Structural invariants: every edge interior xor cross, owner
        // map consistent with the cuts.
        let plan = PartitionPlan::build(&g, shards);
        plan.validate(&g)?;

        // Behavioural parity on a random configuration.
        let cfg = EngineConfig::default()
            .threads(1 + rng.below(4) as usize)
            .bypass(rng.below(2) == 0)
            .layout(if rng.below(2) == 0 {
                Layout::Interleaved
            } else {
                Layout::Externalised
            });
        let session = GraphSession::new(&g);
        let flat = session.run_with(&ConnectedComponents, RunOptions::new().config(cfg));
        let sharded = session.run_with(
            &ConnectedComponents,
            RunOptions::new().config(cfg.shards(shards)),
        );
        if flat.values != sharded.values {
            return Err(format!("values diverge at {shards} shards"));
        }
        if flat.metrics.num_supersteps() != sharded.metrics.num_supersteps() {
            return Err("superstep traces diverge".into());
        }
        // The message split covers the total exactly.
        let m = &sharded.metrics;
        if m.intra_shard_messages + m.cross_shard_messages != m.total_messages() {
            return Err("intra + cross != total messages".into());
        }
        Ok(())
    });
}

#[test]
fn partitioning_none_is_the_flat_engine() {
    let g = gen::grid(12, 12);
    let session = GraphSession::new(&g);
    let r = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(EngineConfig::default().partitioning(Partitioning::None)),
    );
    assert_eq!(r.metrics.shards, 0);
    assert_eq!(r.metrics.shard_edge_imbalance, 0.0);
    assert_eq!(r.metrics.intra_shard_messages, 0);
    assert_eq!(r.metrics.cross_shard_messages, 0);
    assert!(r.metrics.supersteps.iter().all(|s| s.flush_time.is_zero()));
}

#[test]
fn cache_sized_partitioning_picks_shard_count_from_budget() {
    let g = gen::rmat(9, 4, 0.57, 0.19, 0.19, 3); // 512 vertices
    let session = GraphSession::new(&g);
    // 64 bytes/vertex estimate → a 4096-byte budget is 64 vertices per
    // shard → 8 shards for 512 vertices.
    let r = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(
            session
                .config()
                .partitioning(Partitioning::CacheSized { budget_bytes: 4096 }),
        ),
    );
    assert_eq!(r.metrics.shards, 8);
    let flat = session.run(&ConnectedComponents);
    assert_eq!(r.values, flat.values);
}

#[test]
fn edge_centric_bypass_fallback_is_surfaced() {
    let g = gen::barabasi_albert(300, 3, 4);
    let p = Sssp::from_hub(&g);
    let session = GraphSession::new(&g);
    let want = session.run(&p).values;

    // EdgeCentric + bypass: documented fallback, surfaced in metrics —
    // on both substrates — and results unaffected.
    for cfg in [
        EngineConfig::default()
            .schedule(Schedule::EdgeCentric)
            .bypass(true),
        EngineConfig::default()
            .schedule(Schedule::EdgeCentric)
            .bypass(true)
            .shards(4),
    ] {
        let r = session.run_with(&p, RunOptions::new().config(cfg));
        assert_eq!(
            r.metrics.schedule_fallback,
            Some(ScheduleFallback::EdgeCentricBypassRebuild),
            "under {cfg:?}"
        );
        assert_eq!(r.values, want, "under {cfg:?}");
    }

    // No fallback without bypass, or with a different schedule.
    for cfg in [
        EngineConfig::default().schedule(Schedule::EdgeCentric),
        EngineConfig::default()
            .schedule(Schedule::Dynamic { chunk: 64 })
            .bypass(true),
    ] {
        let r = session.run_with(&p, RunOptions::new().config(cfg));
        assert_eq!(r.metrics.schedule_fallback, None, "under {cfg:?}");
        assert_eq!(r.values, want, "under {cfg:?}");
    }
}

#[test]
fn warm_start_and_sharding_compose() {
    let g = gen::barabasi_albert(300, 3, 6);
    let session = GraphSession::new(&g);
    let fixpoint = session.run(&ConnectedComponents);
    let warm = session.run_with(
        &ConnectedComponents,
        RunOptions::new()
            .config(session.config().shards(4))
            .warm_start(&fixpoint.values),
    );
    assert_eq!(warm.values, fixpoint.values);
    assert!(
        warm.metrics.num_supersteps() <= 3,
        "warm start must converge fast under sharding too"
    );
}
