//! End-to-end three-layer validation: graphs run through the AOT-compiled
//! JAX/Pallas artifacts (via PJRT) must agree with the pure-Rust engine.
//!
//! Requires `make artifacts`; tests skip (with a note) when the artifact
//! directory is missing so `cargo test` works on a fresh checkout.

use ipregel::algos::{ConnectedComponents, PageRank, Sssp};
use ipregel::engine::{EngineConfig, GraphSession};
use ipregel::graph::gen;
use ipregel::runtime::{accel, default_artifact_dir, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!(
            "skipping accel tests: {} missing (run `make artifacts`)",
            dir.display()
        );
        return None;
    }
    Some(Runtime::load(&dir).expect("artifacts present but failed to load"))
}

#[test]
fn accel_pagerank_matches_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::barabasi_albert(600, 3, 12);
    let block = accel::DenseBlock::from_graph(&rt, &g).unwrap();
    let accel_ranks = accel::pagerank(&rt, &g, &block).unwrap();

    let engine_ranks = GraphSession::new(&g).run(&PageRank::default());
    assert_eq!(accel_ranks.len(), 600);
    for v in 0..600 {
        let (a, b) = (accel_ranks[v] as f64, engine_ranks.values[v]);
        assert!(
            (a - b).abs() < 1e-6 + b * 1e-4,
            "v{v}: accel {a} vs engine {b}"
        );
    }
}

#[test]
fn accel_sssp_matches_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::rmat(9, 4, 0.57, 0.19, 0.19, 44); // 512 vertices
    let p = Sssp::from_hub(&g);
    let block = accel::DenseBlock::from_graph(&rt, &g).unwrap();
    let accel_dist = accel::sssp(&rt, &g, &block, p.source).unwrap();
    let engine_dist = GraphSession::with_config(&g, EngineConfig::default().bypass(true)).run(&p);
    for v in 0..g.num_vertices() {
        let a = accel_dist[v];
        let b = engine_dist.values[v];
        if b == u64::MAX {
            assert!(a.is_infinite(), "v{v}: accel {a} but engine unreached");
        } else {
            assert_eq!(a as u64, b, "v{v}");
        }
    }
}

#[test]
fn accel_cc_matches_engine() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::disjoint_rings(7, 40); // 280 vertices, 7 components
    let block = accel::DenseBlock::from_graph(&rt, &g).unwrap();
    let accel_labels = accel::connected_components(&rt, &g, &block).unwrap();
    let engine_labels =
        GraphSession::with_config(&g, EngineConfig::default().bypass(true)).run(&ConnectedComponents);
    assert_eq!(accel_labels, engine_labels.values);
}

#[test]
fn accel_single_step_is_one_engine_superstep() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::ring(64);
    let block = accel::DenseBlock::from_graph(&rt, &g).unwrap();
    // Uniform contributions on a 2-regular ring: every vertex gathers
    // 2 * (1/n)/2 = 1/n, so the step returns 0.15/n + 0.85/n = 1/n.
    let n = 64.0f32;
    let contrib: Vec<f32> = vec![1.0 / n / 2.0; 64];
    let out = accel::pagerank_step(&rt, &block, &contrib).unwrap();
    for (v, &r) in out.iter().enumerate() {
        assert!((r - 1.0 / n).abs() < 1e-6, "v{v}: {r}");
    }
}

#[test]
fn accel_rejects_oversized_graphs() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::ring(rt.manifest.n + 1);
    match accel::DenseBlock::from_graph(&rt, &g) {
        Ok(_) => panic!("oversized graph must be rejected"),
        Err(err) => assert!(err.to_string().contains("compiled for n="), "{err}"),
    }
}

#[test]
fn runtime_reports_loaded_artifacts() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.executables();
    for expected in ["pagerank_run", "pagerank_step", "sssp_relax", "cc_label"] {
        assert!(names.contains(&expected), "{names:?}");
    }
    assert!(!rt.platform().is_empty());
    assert_eq!(rt.manifest.n % rt.manifest.tile, 0);
}

#[test]
fn accel_multi_sssp_matches_per_source_engine_runs() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::rmat(9, 4, 0.57, 0.19, 0.19, 91); // 512 vertices
    let block = accel::DenseBlock::from_graph(&rt, &g).unwrap();
    let sources: Vec<u32> = vec![g.max_out_degree_vertex(), 0, 17, 255];
    let all = accel::multi_sssp(&rt, &block, &sources).unwrap();
    assert_eq!(all.len(), sources.len());
    for (k, &src) in sources.iter().enumerate() {
        let engine = GraphSession::with_config(&g, EngineConfig::default().bypass(true))
            .run(&Sssp { source: src });
        for v in 0..g.num_vertices() {
            let a = all[k][v];
            let b = engine.values[v];
            if b == u64::MAX {
                assert!(a.is_infinite(), "src {src} v{v}");
            } else {
                assert_eq!(a as u64, b, "src {src} v{v}");
            }
        }
    }
}

#[test]
fn accel_multi_sssp_validates_inputs() {
    let Some(rt) = runtime_or_skip() else { return };
    let g = gen::ring(64);
    let block = accel::DenseBlock::from_graph(&rt, &g).unwrap();
    assert!(accel::multi_sssp(&rt, &block, &[]).is_err());
    assert!(accel::multi_sssp(&rt, &block, &[64]).is_err());
    let too_many: Vec<u32> = (0..rt.manifest.multi_sources as u32 + 1).collect();
    assert!(accel::multi_sssp(&rt, &block, &too_many).is_err());
}
