//! Memory-system pass acceptance suite (DESIGN.md §2.9).
//!
//! Three contracts:
//!
//! 1. **Bit-identity** — work-stealing shard execution and any prefetch
//!    pipeline depth produce bit-identical values AND identical superstep
//!    traces to the fixed dispatch, across the Strategy × Layout ×
//!    Schedule × Partitioning grid. Stealing moves *whole shards* between
//!    workers; owner-exclusivity inside a shard is untouched, so nothing
//!    a program observes may change.
//! 2. **Vector gather exactness** — pull-mode monoid combiners fold
//!    through the lane-parallel gather of `combine::vector`; results must
//!    equal a serial scalar fixpoint, and the lane counters must prove
//!    the vector path actually ran.
//! 3. **Stealing actually steals** — a seeded shard imbalance (all edge
//!    weight in a few shards, scan work in many weightless ones) must
//!    record at least one steal in `RunMetrics::steals`.

use ipregel::algos::{ConnectedComponents, Sssp};
use ipregel::combine::{MinCombiner, Strategy};
use ipregel::engine::{
    CombinedPlane, Context, EngineConfig, GraphSession, Mode, NoAgg, RunOptions, VertexProgram,
};
use ipregel::graph::csr::{Csr, VertexId};
use ipregel::graph::{gen, GraphBuilder};
use ipregel::layout::Layout;
use ipregel::metrics::RunMetrics;
use ipregel::sched::Schedule;

fn assert_same_trace(a: &RunMetrics, b: &RunMetrics, what: &str) {
    assert_eq!(a.num_supersteps(), b.num_supersteps(), "{what}: superstep count");
    for (i, (x, y)) in a.supersteps.iter().zip(b.supersteps.iter()).enumerate() {
        assert_eq!(
            x.active_vertices, y.active_vertices,
            "{what}: active count at superstep {i}"
        );
        assert_eq!(x.messages, y.messages, "{what}: messages at superstep {i}");
    }
    assert_eq!(a.halt_reason, b.halt_reason, "{what}: halt reason");
}

#[test]
fn memory_pass_is_bit_identical_across_the_grid() {
    let g = gen::rmat(8, 5, 0.57, 0.19, 0.19, 2);
    let session = GraphSession::new(&g);
    for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
        for &layout in &[Layout::Interleaved, Layout::Externalised] {
            for &schedule in &[Schedule::Static, Schedule::EdgeCentric] {
                for &shards in &[0usize, 3] {
                    let base = EngineConfig::default()
                        .threads(4)
                        .strategy(strategy)
                        .layout(layout)
                        .schedule(schedule)
                        .bypass(true)
                        .shards(shards);
                    // Every memory knob, alone and combined: stealing,
                    // shallow and deep prefetch pipelines.
                    let variants = [
                        base.steal(true),
                        base.pipeline_depth(1),
                        base.pipeline_depth(64),
                        base.steal(true).pipeline_depth(4),
                    ];
                    let p = Sssp::from_hub(&g);
                    let cc_ref =
                        session.run_with(&ConnectedComponents, RunOptions::new().config(base));
                    let sssp_ref = session.run_with(&p, RunOptions::new().config(base));
                    for v in variants {
                        let what = format!("{v:?}");
                        let cc =
                            session.run_with(&ConnectedComponents, RunOptions::new().config(v));
                        assert_eq!(cc.values, cc_ref.values, "cc values under {what}");
                        assert_same_trace(
                            &cc_ref.metrics,
                            &cc.metrics,
                            &format!("cc under {what}"),
                        );
                        let sp = session.run_with(&p, RunOptions::new().config(v));
                        assert_eq!(sp.values, sssp_ref.values, "sssp values under {what}");
                        assert_same_trace(
                            &sssp_ref.metrics,
                            &sp.metrics,
                            &format!("sssp under {what}"),
                        );
                    }
                }
            }
        }
    }
}

/// Pull-mode minimum-label propagation: the vector-gather workhorse.
/// Every vertex converges to the smallest label reachable along reverse
/// edges — exact integer min, so any fold order gives the same bits.
struct PullMinLabel;

impl VertexProgram for PullMinLabel {
    type Value = u64;
    type Message = u64;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Pull
    }

    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }

    fn aggregator(&self) -> NoAgg {
        NoAgg
    }

    fn init(&self, _g: &Csr, v: VertexId) -> u64 {
        v as u64
    }

    fn compute<C: Context<u64, u64>>(&self, ctx: &mut C, msg: Option<u64>) {
        let grew = if ctx.superstep() == 0 {
            true
        } else if let Some(m) = msg {
            if m < *ctx.value() {
                *ctx.value_mut() = m;
                true
            } else {
                false
            }
        } else {
            false
        };
        if grew {
            let v = *ctx.value();
            ctx.broadcast(v);
        }
        ctx.vote_to_halt();
    }
}

/// Serial fixpoint of the same propagation: repeatedly take the min of
/// in-neighbour labels until nothing changes.
fn pull_min_reference(g: &Csr) -> Vec<u64> {
    let mut label: Vec<u64> = (0..g.num_vertices() as u64).collect();
    loop {
        let prev = label.clone();
        let mut changed = false;
        for v in g.vertices() {
            if let Some(m) = g.in_neighbors(v).iter().map(|&s| prev[s as usize]).min() {
                if m < label[v as usize] {
                    label[v as usize] = m;
                    changed = true;
                }
            }
        }
        if !changed {
            return label;
        }
    }
}

#[test]
fn vector_gather_matches_the_scalar_fixpoint_and_proves_it_ran() {
    // Mean degree 16: plenty of in-rows past VECTOR_GATHER_MIN, so the
    // lane-parallel gather engages on most vertices.
    let g = gen::rmat(9, 16, 0.57, 0.19, 0.19, 11);
    let want = pull_min_reference(&g);
    let session = GraphSession::new(&g);
    let mut traces: Vec<RunMetrics> = Vec::new();
    for cfg in [
        EngineConfig::default().threads(4),
        EngineConfig::default().threads(4).pipeline_depth(2),
        EngineConfig::default().threads(4).shards(4).steal(true),
        EngineConfig::default().threads(1),
    ] {
        let r = session.run_with(&PullMinLabel, RunOptions::new().config(cfg));
        assert_eq!(r.values, want, "pull-min under {cfg:?}");
        assert!(
            r.metrics.vector_lanes_scanned > 0,
            "vector gather must actually run under {cfg:?}"
        );
        assert!(
            r.metrics.vector_lanes_useful <= r.metrics.vector_lanes_scanned,
            "utilisation is a fraction under {cfg:?}"
        );
        traces.push(r.metrics);
    }
    for t in &traces[1..] {
        assert_same_trace(&traces[0], t, "pull-min config sweep");
        assert_eq!(
            t.vector_lanes_scanned, traces[0].vector_lanes_scanned,
            "lane accounting is schedule-independent"
        );
    }
}

#[test]
fn seeded_shard_imbalance_forces_steals_and_metrics_record_them() {
    // 64 rings of 64 vertices hold ALL the edge weight in the first 4 of
    // 64 shards; 60 shards of isolated vertices carry scan work but zero
    // weight. Weight-balanced cuts therefore strand the weightless
    // shards on one worker, whose peers drain their single heavy shard
    // and must steal. 65 536 active vertices at superstep 0 clears the
    // serial cutoff, so the stealing path genuinely engages.
    let n = 65_536usize;
    let mut edges: Vec<(u32, u32)> = Vec::new();
    for r in 0..64u32 {
        let base = r * 64;
        for i in 0..64u32 {
            edges.push((base + i, base + (i + 1) % 64));
        }
    }
    let g = GraphBuilder::new(n).symmetric(true).edges(&edges).build();
    let fixed_cfg = EngineConfig::default().threads(4).shards(64);
    let steal_cfg = fixed_cfg.steal(true);
    let session = GraphSession::new(&g);
    let fixed = session.run_with(&ConnectedComponents, RunOptions::new().config(fixed_cfg));
    let stolen = session.run_with(&ConnectedComponents, RunOptions::new().config(steal_cfg));
    assert_eq!(stolen.values, fixed.values, "stealing never changes answers");
    assert_same_trace(&fixed.metrics, &stolen.metrics, "seeded imbalance cc");
    assert_eq!(fixed.metrics.steals, 0, "fixed dispatch records no steals");
    assert!(
        stolen.metrics.steals >= 1,
        "seeded imbalance must migrate at least one shard (got {})",
        stolen.metrics.steals
    );
}

#[test]
fn flat_runs_ignore_the_steal_flag_and_record_zero() {
    // Stealing dispatches shards; without a partition plan there is
    // nothing to steal and the flag must be inert.
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 3);
    let session = GraphSession::new(&g);
    let base = EngineConfig::default().threads(4).bypass(true);
    let a = session.run_with(&ConnectedComponents, RunOptions::new().config(base));
    let b = session.run_with(
        &ConnectedComponents,
        RunOptions::new().config(base.steal(true)),
    );
    assert_eq!(a.values, b.values);
    assert_same_trace(&a.metrics, &b.metrics, "flat steal flag");
    assert_eq!(b.metrics.steals, 0);
}
