//! GraphSession integration tests: pooled-state reuse must be
//! bit-invisible (reused runs give bit-identical results to fresh
//! sessions), warm starts must actually save work, halt policies must
//! fire, concurrent use must be safe, and the deprecated `engine::run`
//! shim must behave exactly like a throwaway session.

use ipregel::algos::{
    reference, ConnectedComponents, DanglingPageRank, KCore, PageRank, Sssp, WeightedSssp,
};
use ipregel::combine::Strategy;
use ipregel::engine::{EngineConfig, GraphSession, Halt, RunOptions};
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::metrics::HaltReason;
use ipregel::sched::Schedule;

#[test]
fn session_reuse_is_bit_identical_to_fresh_sessions() {
    let g = gen::rmat(9, 5, 0.57, 0.19, 0.19, 7);
    let cfg = EngineConfig::default().threads(4).bypass(true);

    // Two consecutive runs on ONE session (second reuses pooled state)…
    let shared = GraphSession::with_config(&g, cfg);
    let a1 = shared.run(&ConnectedComponents);
    let a2 = shared.run(&ConnectedComponents);
    assert!(!a1.metrics.store_reused);
    assert!(a2.metrics.store_reused);

    // …must equal two runs on TWO fresh sessions, bit for bit.
    let b1 = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
    let b2 = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
    assert_eq!(a1.values, b1.values);
    assert_eq!(a2.values, b2.values);
    assert_eq!(a1.values, a2.values);
    assert_eq!(
        a1.metrics.num_supersteps(),
        a2.metrics.num_supersteps(),
        "reuse must not change the superstep trace"
    );

    // Same property for a float-valued program (f64 bit-exactness).
    let p1 = shared.run(&PageRank::default());
    let p2 = shared.run(&PageRank::default());
    let fresh = GraphSession::with_config(&g, cfg).run(&PageRank::default());
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&p1.values), bits(&p2.values));
    assert_eq!(bits(&p1.values), bits(&fresh.values));
}

#[test]
fn interleaved_program_types_still_reuse_correctly() {
    // Alternate programs with different (Value, Message) types; each type
    // keeps its own pooled store and results never bleed across.
    let g = gen::barabasi_albert(400, 3, 21);
    let session = GraphSession::new(&g);
    let cc_want = reference::connected_components(&g);
    let pr_want = reference::pagerank(&g, 10, 0.85);
    for round in 0..3 {
        let cc = session.run(&ConnectedComponents);
        assert_eq!(cc.values, cc_want, "round {round}");
        let pr = session.run(&PageRank::default());
        for v in g.vertices() {
            assert!(
                (pr.values[v as usize] - pr_want[v as usize]).abs() < 1e-12,
                "round {round} v{v}"
            );
        }
        let kc = session.run(&KCore { k: 2 });
        assert!(kc.values.iter().any(|s| s.alive), "round {round}");
        if round > 0 {
            assert!(cc.metrics.store_reused && pr.metrics.store_reused);
        }
    }
}

#[test]
fn warm_start_converges_in_fewer_supersteps() {
    // Cold CC on a high-diameter graph needs O(diameter) supersteps;
    // warm-started from the fixpoint it must settle almost immediately.
    let g = gen::grid(40, 40);
    let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
    let cold = session.run(&ConnectedComponents);
    assert!(cold.metrics.num_supersteps() > 10);

    let warm = session.run_with(
        &ConnectedComponents,
        RunOptions::new().warm_start(&cold.values),
    );
    assert_eq!(warm.values, cold.values);
    assert!(
        warm.metrics.num_supersteps() <= 3,
        "warm start took {} supersteps vs cold {}",
        warm.metrics.num_supersteps(),
        cold.metrics.num_supersteps()
    );
    assert!(warm.metrics.total_activations() < cold.metrics.total_activations());
}

#[test]
fn warm_start_with_stale_values_still_reaches_the_fixpoint() {
    // Warm-starting from a *partially* converged state (labels of a
    // coarser run) must still land on the exact fixpoint: min-label
    // propagation is self-correcting downward.
    let g = gen::disjoint_rings(3, 60);
    let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
    let want = reference::connected_components(&g);
    // Stale start: everyone still believes their own id (a fully
    // unconverged state supplied through the warm-start path).
    let stale: Vec<u32> = g.vertices().collect();
    let r = session.run_with(&ConnectedComponents, RunOptions::new().warm_start(&stale));
    assert_eq!(r.values, want);
}

#[test]
fn concurrent_runs_on_one_session_are_safe_and_correct() {
    let g = gen::barabasi_albert(600, 4, 5);
    let session = GraphSession::with_config(&g, EngineConfig::default().threads(2));
    let want = reference::connected_components(&g);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let session = &session;
                s.spawn(move || session.run(&ConnectedComponents).values)
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), want);
        }
    });
    assert_eq!(session.runs_completed(), 4);
}

#[test]
fn per_run_overrides_cover_the_whole_switch_grid() {
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 12);
    let p = Sssp::from_hub(&g);
    let want = reference::bfs_levels(&g, p.source);
    let session = GraphSession::new(&g);
    for (strategy, layout, schedule) in [
        (Strategy::Hybrid, Layout::Externalised, Schedule::Dynamic { chunk: 32 }),
        (Strategy::Lock, Layout::Interleaved, Schedule::EdgeCentric),
        (Strategy::CasNeutral, Layout::Externalised, Schedule::Static),
    ] {
        let cfg = EngineConfig::default()
            .threads(3)
            .strategy(strategy)
            .layout(layout)
            .schedule(schedule)
            .bypass(true);
        let got = session.run_with(&p, RunOptions::new().config(cfg));
        assert_eq!(got.values, want, "{strategy:?}/{layout:?}/{schedule:?}");
    }
}

#[test]
fn halt_policies_compose_with_sessions() {
    let g = gen::path(500);
    let session = GraphSession::new(&g);

    // Superstep cap fires first on a long path.
    let capped = session.run_with(
        &ConnectedComponents,
        RunOptions::new().halt(Halt::supersteps(5)),
    );
    assert_eq!(capped.metrics.halt_reason, HaltReason::SuperstepCap);
    assert_eq!(capped.metrics.num_supersteps(), 5);

    // Quiescence on an unconstrained run.
    let free = session.run(&ConnectedComponents);
    assert_eq!(free.metrics.halt_reason, HaltReason::Quiescence);

    // Aggregator convergence composed with a cap: the directed path's
    // tail vertex is dangling, so the aggregator stream is live and one
    // of the two composed conditions must end the run before the
    // program's own 400-iteration bound.
    let converging = session.run_with(
        &DanglingPageRank {
            iterations: 400,
            damping: 0.85,
        },
        RunOptions::new().halt(
            Halt::converged(|a: Option<&f64>, b: Option<&f64>| {
                matches!((a, b), (Some(x), Some(y)) if (x - y).abs() < 1e-13)
            })
            .and_supersteps(300),
        ),
    );
    assert_ne!(converging.metrics.halt_reason, HaltReason::Quiescence);
    assert!(
        converging.metrics.num_supersteps() <= 300,
        "{}",
        converging.metrics.num_supersteps()
    );
}

#[test]
#[allow(deprecated)]
fn deprecated_run_shim_matches_session_exactly() {
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 44);
    let cfg = EngineConfig::default().threads(4).bypass(true);
    let p = Sssp::from_hub(&g);
    let via_shim = ipregel::engine::run(&g, &p, cfg);
    let via_session = GraphSession::with_config(&g, cfg).run(&p);
    assert_eq!(via_shim.values, via_session.values);
    assert_eq!(
        via_shim.metrics.num_supersteps(),
        via_session.metrics.num_supersteps()
    );

    let wg = gen::randomly_weighted(&g, 1.0, 2.0, 3);
    let wp = WeightedSssp::from_hub(&wg);
    let shim_w = ipregel::engine::run(&wg, &wp, cfg);
    let session_w = GraphSession::with_config(&wg, cfg).run(&wp);
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&shim_w.values), bits(&session_w.values));
}
