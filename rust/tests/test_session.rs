//! GraphSession integration tests: pooled-state reuse must be
//! bit-invisible (reused runs give bit-identical results to fresh
//! sessions), warm starts must actually save work, halt policies must
//! fire (including their composition edge cases), and concurrent use
//! must be safe.

use ipregel::algos::{
    reference, ConnectedComponents, DanglingPageRank, KCore, PageRank, Sssp,
};
use ipregel::combine::{MinCombiner, Strategy};
use ipregel::engine::{
    CombinedPlane, Context, EngineConfig, GraphSession, Halt, Mode, NoAgg, RunOptions,
    VertexProgram,
};
use ipregel::graph::csr::{Csr, VertexId};
use ipregel::graph::gen;
use ipregel::layout::Layout;
use ipregel::metrics::HaltReason;
use ipregel::sched::Schedule;

#[test]
fn session_reuse_is_bit_identical_to_fresh_sessions() {
    let g = gen::rmat(9, 5, 0.57, 0.19, 0.19, 7);
    let cfg = EngineConfig::default().threads(4).bypass(true);

    // Two consecutive runs on ONE session (second reuses pooled state)…
    let shared = GraphSession::with_config(&g, cfg);
    let a1 = shared.run(&ConnectedComponents);
    let a2 = shared.run(&ConnectedComponents);
    assert!(!a1.metrics.store_reused);
    assert!(a2.metrics.store_reused);

    // …must equal two runs on TWO fresh sessions, bit for bit.
    let b1 = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
    let b2 = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
    assert_eq!(a1.values, b1.values);
    assert_eq!(a2.values, b2.values);
    assert_eq!(a1.values, a2.values);
    assert_eq!(
        a1.metrics.num_supersteps(),
        a2.metrics.num_supersteps(),
        "reuse must not change the superstep trace"
    );

    // Same property for a float-valued program (f64 bit-exactness).
    let p1 = shared.run(&PageRank::default());
    let p2 = shared.run(&PageRank::default());
    let fresh = GraphSession::with_config(&g, cfg).run(&PageRank::default());
    let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&p1.values), bits(&p2.values));
    assert_eq!(bits(&p1.values), bits(&fresh.values));
}

#[test]
fn interleaved_program_types_still_reuse_correctly() {
    // Alternate programs with different (Value, Message) types; each type
    // keeps its own pooled store and results never bleed across.
    let g = gen::barabasi_albert(400, 3, 21);
    let session = GraphSession::new(&g);
    let cc_want = reference::connected_components(&g);
    let pr_want = reference::pagerank(&g, 10, 0.85);
    for round in 0..3 {
        let cc = session.run(&ConnectedComponents);
        assert_eq!(cc.values, cc_want, "round {round}");
        let pr = session.run(&PageRank::default());
        for v in g.vertices() {
            assert!(
                (pr.values[v as usize] - pr_want[v as usize]).abs() < 1e-12,
                "round {round} v{v}"
            );
        }
        let kc = session.run(&KCore { k: 2 });
        assert!(kc.values.iter().any(|s| s.alive), "round {round}");
        if round > 0 {
            assert!(cc.metrics.store_reused && pr.metrics.store_reused);
        }
    }
}

#[test]
fn warm_start_converges_in_fewer_supersteps() {
    // Cold CC on a high-diameter graph needs O(diameter) supersteps;
    // warm-started from the fixpoint it must settle almost immediately.
    let g = gen::grid(40, 40);
    let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
    let cold = session.run(&ConnectedComponents);
    assert!(cold.metrics.num_supersteps() > 10);

    let warm = session.run_with(
        &ConnectedComponents,
        RunOptions::new().warm_start(&cold.values),
    );
    assert_eq!(warm.values, cold.values);
    assert!(
        warm.metrics.num_supersteps() <= 3,
        "warm start took {} supersteps vs cold {}",
        warm.metrics.num_supersteps(),
        cold.metrics.num_supersteps()
    );
    assert!(warm.metrics.total_activations() < cold.metrics.total_activations());
}

#[test]
fn warm_start_with_stale_values_still_reaches_the_fixpoint() {
    // Warm-starting from a *partially* converged state (labels of a
    // coarser run) must still land on the exact fixpoint: min-label
    // propagation is self-correcting downward.
    let g = gen::disjoint_rings(3, 60);
    let session = GraphSession::with_config(&g, EngineConfig::default().bypass(true));
    let want = reference::connected_components(&g);
    // Stale start: everyone still believes their own id (a fully
    // unconverged state supplied through the warm-start path).
    let stale: Vec<u32> = g.vertices().collect();
    let r = session.run_with(&ConnectedComponents, RunOptions::new().warm_start(&stale));
    assert_eq!(r.values, want);
}

#[test]
fn concurrent_runs_on_one_session_are_safe_and_correct() {
    let g = gen::barabasi_albert(600, 4, 5);
    let session = GraphSession::with_config(&g, EngineConfig::default().threads(2));
    let want = reference::connected_components(&g);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let session = &session;
                s.spawn(move || session.run(&ConnectedComponents).values)
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), want);
        }
    });
    assert_eq!(session.runs_completed(), 4);
}

#[test]
fn per_run_overrides_cover_the_whole_switch_grid() {
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 12);
    let p = Sssp::from_hub(&g);
    let want = reference::bfs_levels(&g, p.source);
    let session = GraphSession::new(&g);
    for (strategy, layout, schedule) in [
        (Strategy::Hybrid, Layout::Externalised, Schedule::Dynamic { chunk: 32 }),
        (Strategy::Lock, Layout::Interleaved, Schedule::EdgeCentric),
        (Strategy::CasNeutral, Layout::Externalised, Schedule::Static),
    ] {
        let cfg = EngineConfig::default()
            .threads(3)
            .strategy(strategy)
            .layout(layout)
            .schedule(schedule)
            .bypass(true);
        let got = session.run_with(&p, RunOptions::new().config(cfg));
        assert_eq!(got.values, want, "{strategy:?}/{layout:?}/{schedule:?}");
    }
}

#[test]
fn halt_policies_compose_with_sessions() {
    let g = gen::path(500);
    let session = GraphSession::new(&g);

    // Superstep cap fires first on a long path.
    let capped = session.run_with(
        &ConnectedComponents,
        RunOptions::new().halt(Halt::supersteps(5)),
    );
    assert_eq!(capped.metrics.halt_reason, HaltReason::SuperstepCap);
    assert_eq!(capped.metrics.num_supersteps(), 5);

    // Quiescence on an unconstrained run.
    let free = session.run(&ConnectedComponents);
    assert_eq!(free.metrics.halt_reason, HaltReason::Quiescence);

    // Aggregator convergence composed with a cap: the directed path's
    // tail vertex is dangling, so the aggregator stream is live and one
    // of the two composed conditions must end the run before the
    // program's own 400-iteration bound.
    let converging = session.run_with(
        &DanglingPageRank {
            iterations: 400,
            damping: 0.85,
        },
        RunOptions::new().halt(
            Halt::converged(|a: Option<&f64>, b: Option<&f64>| {
                matches!((a, b), (Some(x), Some(y)) if (x - y).abs() < 1e-13)
            })
            .and_supersteps(300),
        ),
    );
    assert_ne!(converging.metrics.halt_reason, HaltReason::Quiescence);
    assert!(
        converging.metrics.num_supersteps() <= 300,
        "{}",
        converging.metrics.num_supersteps()
    );
}

/// A program that never activates: every vertex starts inactive and the
/// user function would diverge if it ever ran — exercising the
/// quiescence edge case of an empty initial frontier.
struct Dormant;

impl VertexProgram for Dormant {
    type Value = u32;
    type Message = u32;
    type Comb = MinCombiner;
    type Agg = NoAgg;
    type Delivery = CombinedPlane;

    fn mode(&self) -> Mode {
        Mode::Push
    }
    fn combiner(&self) -> MinCombiner {
        MinCombiner
    }
    fn aggregator(&self) -> NoAgg {
        NoAgg
    }
    fn init(&self, _g: &Csr, v: VertexId) -> u32 {
        v
    }
    fn initially_active(&self, _g: &Csr, _v: VertexId) -> bool {
        false
    }
    fn compute<C: Context<u32, u32>>(&self, _ctx: &mut C, _msg: Option<u32>) {
        panic!("no vertex may ever run: the initial active set is empty");
    }
}

#[test]
fn zero_initially_active_vertices_quiesce_in_zero_supersteps() {
    let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 3);
    let session = GraphSession::new(&g);
    for cfg in [
        EngineConfig::default(),
        EngineConfig::default().bypass(true),
        EngineConfig::default().shards(4),
        EngineConfig::default().shards(4).bypass(true),
    ] {
        let r = session.run_with(&Dormant, RunOptions::new().config(cfg));
        assert_eq!(r.metrics.halt_reason, HaltReason::Quiescence, "{cfg:?}");
        assert_eq!(r.metrics.num_supersteps(), 0, "{cfg:?}");
        assert_eq!(r.metrics.total_messages(), 0, "{cfg:?}");
        // Values are the init values, untouched.
        assert_eq!(r.values, g.vertices().collect::<Vec<u32>>(), "{cfg:?}");
    }
    // A Halt policy on top changes nothing: quiescence fires first even
    // with a zero-superstep cap or an always-true convergence predicate.
    let r = session.run_with(
        &Dormant,
        RunOptions::new().halt(Halt::supersteps(0).and_converged(|_: Option<&()>, _| true)),
    );
    assert_eq!(r.metrics.halt_reason, HaltReason::Quiescence);
    assert_eq!(r.metrics.num_supersteps(), 0);
}

#[test]
fn halt_supersteps_and_converged_compose_first_to_fire_wins() {
    let g = gen::path(300);
    let session = GraphSession::new(&g);
    let p = DanglingPageRank {
        iterations: 400,
        damping: 0.85,
    };
    // Tolerance loose enough that convergence fires well before the cap…
    let tol = 1e-6;
    let converged_first = session.run_with(
        &p,
        RunOptions::new().halt(
            Halt::converged(move |a: Option<&f64>, b: Option<&f64>| {
                matches!((a, b), (Some(x), Some(y)) if (x - y).abs() < tol)
            })
            .and_supersteps(350),
        ),
    );
    assert_eq!(converged_first.metrics.halt_reason, HaltReason::Converged);
    let converged_at = converged_first.metrics.num_supersteps();
    assert!(converged_at < 350, "tolerance never fired: {converged_at}");

    // …then a cap *below* the convergence superstep must win instead,
    // with the same predicate installed.
    let cap = converged_at - 1;
    let capped = session.run_with(
        &p,
        RunOptions::new().halt(
            Halt::converged(move |a: Option<&f64>, b: Option<&f64>| {
                matches!((a, b), (Some(x), Some(y)) if (x - y).abs() < tol)
            })
            .and_supersteps(cap),
        ),
    );
    assert_eq!(capped.metrics.halt_reason, HaltReason::SuperstepCap);
    assert_eq!(capped.metrics.num_supersteps(), cap);

    // and_supersteps composes by tightening: a later, looser cap cannot
    // relax an earlier tight one (order must not matter).
    let h: Halt<f64> = Halt::supersteps(7).and_supersteps(100);
    assert_eq!(h.max_supersteps, Some(7));
    let h2: Halt<f64> = Halt::supersteps(100).and_supersteps(7);
    assert_eq!(h2.max_supersteps, Some(7));
}

#[test]
fn converged_predicate_is_not_consulted_while_aggregator_stream_is_silent() {
    // ConnectedComponents aggregates nothing, so an |a, b| a == b
    // predicate would be (None, None)-true at the first barrier; the
    // engine must keep it muzzled and run to the real fixpoint.
    let g = gen::grid(12, 12);
    let session = GraphSession::new(&g);
    let r = session.run_with(
        &ConnectedComponents,
        RunOptions::new().halt(Halt::converged(|a: Option<&()>, b: Option<&()>| a == b)),
    );
    assert_eq!(r.metrics.halt_reason, HaltReason::Quiescence);
    assert_eq!(r.values, reference::connected_components(&g));
}

#[test]
fn message_log_pool_keys_by_message_type_and_survives_epoch_bumps() {
    use ipregel::algos::{Lpa, Triangles};
    use ipregel::graph::GraphBuilder;

    // Triangles requires a simple symmetric graph; LPA runs on anything.
    // Build one graph both can share so the pool genuinely alternates.
    let raw = gen::rmat(7, 4, 0.57, 0.19, 0.19, 33);
    let edges: Vec<(u32, u32)> = raw.edges().collect();
    let g = GraphBuilder::new(raw.num_vertices())
        .symmetric(true)
        .dedup(true)
        .drop_self_loops(true)
        .edges(&edges)
        .build();

    // Lpa messages are u32, Triangles messages are u64: TypeId keying
    // must give each its own pooled MessageLog — a shared slot would
    // hand one program the other's log shape.
    let session = GraphSession::new(&g);
    let l1 = session.run(&Lpa { rounds: 3 });
    assert!(!l1.metrics.plane_reused);
    assert_eq!(session.pooled_planes(), 1);
    let t1 = session.run(&Triangles);
    assert!(
        !t1.metrics.plane_reused,
        "different message type must not reuse the u32 log"
    );
    assert_eq!(session.pooled_planes(), 2, "one pooled log per message type");
    let l2 = session.run(&Lpa { rounds: 3 });
    let t2 = session.run(&Triangles);
    assert!(l2.metrics.plane_reused && t2.metrics.plane_reused);
    assert_eq!(l2.values, l1.values, "pooled u32 log must be bit-invisible");
    assert_eq!(t2.values, t1.values, "pooled u64 log must be bit-invisible");
    assert_eq!(session.pooled_planes(), 2);
}

#[test]
fn pooled_message_log_is_not_stale_across_a_graph_mutation_epoch() {
    use ipregel::algos::Lpa;
    use ipregel::graph::dynamic::{DynamicGraph, MutationSet};

    let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 41);
    let mut session = GraphSession::dynamic_with_config(
        DynamicGraph::with_spill_threshold(g, 1_000_000),
        EngineConfig::default(),
    );
    let p = Lpa { rounds: 4 };
    let before = session.run(&p);
    assert_eq!(before.metrics.graph_epoch, 0);

    // Bump the mutation epoch; the pooled log was primed against epoch 0
    // and must be checked out clean, not replayed.
    let mut m = MutationSet::new();
    m.insert_undirected(0, 50);
    m.insert_undirected(3, 97);
    let receipt = session.apply_mutations(&m).unwrap();
    assert_eq!(receipt.epoch, 1);

    let after = session.run(&p);
    assert_eq!(after.metrics.graph_epoch, 1);
    assert!(after.metrics.plane_reused, "same message type: pool hit");

    // Ground truth: a throwaway session over the compacted rebuild.
    let rebuilt = session.graph().rebuilt();
    let want = GraphSession::new(&rebuilt).run(&p);
    assert_eq!(
        after.values, want.values,
        "a stale-epoch or dirty pooled log would diverge here"
    );
}
