//! Observability-plane integration tests (DESIGN.md §2.10).
//!
//! The plane's contract is threefold: tracing must be *transparent*
//! (bit-identical results and superstep counts with tracing on or off,
//! across the whole configuration grid), *faithful* (spans nest and
//! order like the phases that produced them; steal instants agree with
//! the engine's measured steal counter), and *portable* (the Chrome
//! trace-event export is structurally sound, and the simulator emits
//! the same schema over its virtual clock).

#[cfg(not(feature = "no-trace"))]
mod traced {
    use ipregel::algos::{ConnectedComponents, PageRank, Sssp};
    use ipregel::combine::Strategy;
    use ipregel::engine::{EngineConfig, GraphSession, Partitioning, RunOptions};
    use ipregel::graph::gen;
    use ipregel::layout::Layout;
    use ipregel::sched::Schedule;
    use ipregel::sim::SimEngine;
    use ipregel::trace::{chrome_trace_json, render_summary, Event, InstantKind, Phase};
    use std::collections::BTreeMap;

    /// Strategy × Layout × Schedule × Partitioning — the grid the
    /// transparency claim is tested over (steal rides on the sharded
    /// configurations, adaptive is exercised separately).
    fn grid() -> Vec<EngineConfig> {
        let mut cfgs = Vec::new();
        for &strategy in &[Strategy::Lock, Strategy::CasNeutral, Strategy::Hybrid] {
            for &layout in &[Layout::Interleaved, Layout::Externalised] {
                for &schedule in &[Schedule::Static, Schedule::Dynamic { chunk: 32 }] {
                    for &(partitioning, steal) in &[
                        (Partitioning::None, false),
                        (Partitioning::Shards(8), false),
                        (Partitioning::Shards(8), true),
                    ] {
                        cfgs.push(
                            EngineConfig::default()
                                .threads(4)
                                .strategy(strategy)
                                .layout(layout)
                                .schedule(schedule)
                                .partitioning(partitioning)
                                .steal(steal),
                        );
                    }
                }
            }
        }
        cfgs
    }

    #[test]
    fn tracing_is_bit_transparent_across_the_grid() {
        let g = gen::rmat(9, 6, 0.57, 0.19, 0.19, 11);
        let session = GraphSession::new(&g);
        let p = PageRank::default();
        for cfg in grid() {
            let plain = session.run_with(&p, RunOptions::new().config(cfg));
            let traced = session.run_with(&p, RunOptions::new().config(cfg.trace(true)));
            assert_eq!(plain.values, traced.values, "values drift under {cfg:?}");
            assert_eq!(
                plain.metrics.num_supersteps(),
                traced.metrics.num_supersteps(),
                "superstep drift under {cfg:?}"
            );
            assert_eq!(
                plain.metrics.total_messages(),
                traced.metrics.total_messages(),
                "message drift under {cfg:?}"
            );
            assert!(plain.metrics.trace.is_none(), "untraced run carries a trace");
            let tr = traced.metrics.trace.as_ref().expect("traced run lost its trace");
            assert_eq!(tr.workers, 4, "one lane per worker under {cfg:?}");
            assert!(!tr.events.is_empty(), "empty trace under {cfg:?}");
        }
    }

    #[test]
    fn tracing_is_transparent_under_the_adaptive_tuner() {
        let g = gen::barabasi_albert(800, 4, 5);
        let session = GraphSession::new(&g);
        let p = Sssp::from_hub(&g);
        for &partitioning in &[Partitioning::None, Partitioning::Shards(8)] {
            let cfg = EngineConfig::default()
                .threads(4)
                .adaptive(true)
                .steal(true)
                .partitioning(partitioning)
                .bypass(true);
            let plain = session.run_with(&p, RunOptions::new().config(cfg));
            let traced = session.run_with(&p, RunOptions::new().config(cfg.trace(true)));
            assert_eq!(plain.values, traced.values, "{partitioning:?}");
            assert_eq!(
                plain.metrics.num_supersteps(),
                traced.metrics.num_supersteps(),
                "{partitioning:?}"
            );
            // The tuner's decision stream must be identical too: the trace
            // plane peeks the contention probes, it never drains them.
            assert_eq!(
                plain.metrics.tuner_decisions.len(),
                traced.metrics.tuner_decisions.len(),
                "{partitioning:?}"
            );
            let tr = traced.metrics.trace.as_ref().expect("trace");
            let decisions = tr
                .events
                .iter()
                .filter(|e| {
                    matches!(e, Event::Instant { kind: InstantKind::TunerDecision { .. }, .. })
                })
                .count();
            assert_eq!(
                decisions,
                traced.metrics.tuner_decisions.len(),
                "one tuner instant per decision {partitioning:?}"
            );
        }
    }

    /// Per-superstep span layout of a partitioned run: every worker
    /// scatter span ends before any flush span starts, every flush span
    /// ends before the apply span starts, and spans on one lane never
    /// overlap.
    #[test]
    fn partitioned_phases_are_ordered_and_lanes_are_sequential() {
        let g = gen::rmat(9, 6, 0.57, 0.19, 0.19, 23);
        let cfg = EngineConfig::default()
            .threads(4)
            .partitioning(Partitioning::Shards(8))
            .trace(true);
        let r = GraphSession::with_config(&g, cfg).run(&PageRank::default());
        let tr = r.metrics.trace.as_ref().expect("trace");

        let mut by_step: BTreeMap<u32, Vec<(Phase, u64, u64)>> = BTreeMap::new();
        let mut by_lane: BTreeMap<u32, Vec<(u64, u64)>> = BTreeMap::new();
        for ev in &tr.events {
            if let Event::Span { tid, superstep, phase, start_ns, end_ns, .. } = ev {
                assert!(end_ns >= start_ns, "negative span");
                assert!(*tid <= tr.engine_lane(), "unknown lane {tid}");
                by_step.entry(*superstep).or_default().push((*phase, *start_ns, *end_ns));
                by_lane.entry(*tid).or_default().push((*start_ns, *end_ns));
            }
        }
        assert!(!by_step.is_empty());
        for (step, spans) in &by_step {
            let max_end = |p: Phase| spans.iter().filter(|s| s.0 == p).map(|s| s.2).max();
            let min_start = |p: Phase| spans.iter().filter(|s| s.0 == p).map(|s| s.1).min();
            if let (Some(se), Some(fs)) = (max_end(Phase::Scatter), min_start(Phase::Flush)) {
                assert!(se <= fs, "step {step}: scatter ends {se} after flush starts {fs}");
            }
            if let (Some(fe), Some(aps)) = (max_end(Phase::Flush), min_start(Phase::Apply)) {
                assert!(fe <= aps, "step {step}: flush ends {fe} after apply starts {aps}");
            }
        }
        for (lane, spans) in by_lane.iter_mut() {
            spans.sort_unstable();
            for w in spans.windows(2) {
                assert!(
                    w[1].0 >= w[0].1,
                    "lane {lane}: overlapping spans {:?} and {:?}",
                    w[0],
                    w[1]
                );
            }
        }
        // One irregularity sample per superstep, and measured shard times
        // feed the metrics-side NUMA vector.
        let counters = tr.events.iter().filter(|e| matches!(e, Event::Counter { .. })).count();
        assert_eq!(counters, r.metrics.num_supersteps(), "one sample per superstep");
        assert_eq!(r.metrics.shard_times.len(), 8, "measured per-shard times");
        assert!(r.metrics.shard_times.iter().any(|d| d.as_nanos() > 0));
    }

    /// Steal attribution: every stolen-shard execution records exactly
    /// one instant, so the trace's steal count equals the engine's
    /// measured counter for the same run.
    #[test]
    fn steal_instants_match_the_measured_steal_counter() {
        // Star graph: one hot shard, so stealing reliably has material.
        let g = gen::star(4000);
        let cfg = EngineConfig::default()
            .threads(4)
            .partitioning(Partitioning::Shards(8))
            .steal(true)
            .trace(true);
        let r = GraphSession::with_config(&g, cfg).run(&ConnectedComponents);
        let tr = r.metrics.trace.as_ref().expect("trace");
        assert_eq!(
            tr.steal_instants() as u64,
            r.metrics.steals,
            "steal instants vs RunMetrics::steals"
        );
        let stolen_spans = tr
            .events
            .iter()
            .filter(|e| matches!(e, Event::Span { shard: Some((_, true)), .. }))
            .count();
        assert_eq!(stolen_spans as u64, r.metrics.steals, "stolen spans vs steals");
    }

    #[test]
    fn chrome_export_is_structurally_sound() {
        let g = gen::rmat(8, 5, 0.57, 0.19, 0.19, 7);
        let cfg = EngineConfig::default()
            .threads(4)
            .partitioning(Partitioning::Shards(4))
            .steal(true)
            .adaptive(true)
            .trace(true);
        let r = GraphSession::with_config(&g, cfg).run(&PageRank::default());
        let tr = r.metrics.trace.as_ref().expect("trace");
        let j = chrome_trace_json(tr);
        assert!(j.starts_with("{\"traceEvents\":[\n"));
        assert!(j.trim_end().ends_with("]}"));
        // Balanced structure (mode strings contain only balanced braces)
        // and strictly finite numbers — Perfetto rejects NaN/Infinity.
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
        assert!(!j.contains("NaN") && !j.contains("inf"), "non-finite number leaked");
        // One metadata record per lane plus the process record.
        let meta = j.matches("\"ph\":\"M\"").count();
        assert_eq!(meta, tr.workers + 2);
        assert!(j.contains("\"name\":\"engine\""));
        assert!(j.contains("\"name\":\"shard-skew\""));
        // The summary sink renders the same trace.
        let s = render_summary(tr, 3);
        assert!(s.starts_with("== trace summary: 4 workers"));
        assert!(s.contains("slowest shards:"), "{s}");
    }

    /// The simulator emits the same schema over its virtual clock, and
    /// the plane must not perturb the virtual time it reports.
    #[test]
    fn sim_emits_the_same_schema_on_the_virtual_clock() {
        let g = gen::rmat(8, 5, 0.57, 0.19, 0.19, 19);
        let p = PageRank::default();
        for &partitioning in &[Partitioning::None, Partitioning::Shards(8)] {
            let cfg = EngineConfig::default()
                .threads(8)
                .partitioning(partitioning)
                .steal(true);
            let plain = SimEngine::new(&g, &p, cfg).run();
            let traced = SimEngine::new(&g, &p, cfg.trace(true)).run();
            assert!(plain.trace.is_none());
            assert_eq!(plain.values, traced.values, "{partitioning:?}");
            assert_eq!(plain.supersteps, traced.supersteps, "{partitioning:?}");
            assert_eq!(
                plain.virtual_seconds, traced.virtual_seconds,
                "trace perturbed the virtual clock {partitioning:?}"
            );
            let tr = traced.trace.as_ref().expect("sim trace");
            assert_eq!(tr.workers, 8);
            let spans = tr.events.iter().filter(|e| matches!(e, Event::Span { .. })).count();
            assert!(spans > 0, "sim emitted no spans {partitioning:?}");
            let counters =
                tr.events.iter().filter(|e| matches!(e, Event::Counter { .. })).count();
            assert_eq!(counters, traced.supersteps, "one sample per virtual superstep");
            // Virtual spans respect lane bounds and the virtual clock's
            // monotonicity, so both sinks accept them unchanged.
            for ev in &tr.events {
                if let Event::Span { tid, start_ns, end_ns, .. } = ev {
                    assert!(*tid <= tr.engine_lane());
                    assert!(end_ns >= start_ns);
                }
            }
            let j = chrome_trace_json(tr);
            assert_eq!(j.matches('{').count(), j.matches('}').count());
            assert!(render_summary(tr, 2).starts_with("== trace summary"));
        }
    }

    /// Session pooling: trace buffers checked out per traced run return
    /// to the pool afterwards, so a session alternating traced/untraced
    /// runs allocates one buffer set, not one per run.
    #[test]
    fn session_pools_trace_buffers_across_runs() {
        let g = gen::barabasi_albert(400, 3, 3);
        let session = GraphSession::new(&g);
        let p = ConnectedComponents;
        assert_eq!(session.pooled_traces(), 0);
        let base = EngineConfig::default().threads(4);
        for _ in 0..3 {
            let traced = session.run_with(&p, RunOptions::new().config(base.trace(true)));
            assert!(traced.metrics.trace.is_some());
            let plain = session.run_with(&p, RunOptions::new().config(base));
            assert!(plain.metrics.trace.is_none());
            assert_eq!(session.pooled_traces(), 1, "buffers recycled, not re-allocated");
        }
    }
}

/// `--features no-trace` compiles the plane out: the construction gates
/// return `None`, so a run *requesting* tracing still yields no trace.
#[cfg(feature = "no-trace")]
mod compiled_out {
    use ipregel::algos::PageRank;
    use ipregel::engine::{EngineConfig, GraphSession};
    use ipregel::graph::gen;
    use ipregel::sim::SimEngine;
    use ipregel::trace::RunTrace;

    #[test]
    fn no_trace_feature_disables_collection_entirely() {
        let g = gen::rmat(8, 5, 0.57, 0.19, 0.19, 7);
        let cfg = EngineConfig::default().threads(4).trace(true);
        let r = GraphSession::with_config(&g, cfg).run(&PageRank::default());
        assert!(r.metrics.trace.is_none());
        assert!(r.metrics.shard_times.is_empty());
        let sim = SimEngine::new(&g, &PageRank::default(), cfg).run();
        assert!(sim.trace.is_none());
        assert!(RunTrace::for_run(true, 4).is_none());
    }
}
