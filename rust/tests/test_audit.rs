//! Integration tests for `pallas-audit` (the `ipregel audit` subcommand).
//!
//! Two halves:
//!   1. **Self-audit**: the shipped tree must satisfy every invariant
//!      against the shipped manifest — this is the same gate CI runs.
//!   2. **Known-bad fixtures**: seeded violations must produce the
//!      expected rule at the expected file:line, so we know the analyzer
//!      actually fires (a checker that never fails checks nothing).

use ipregel::audit::manifest::Manifest;
use ipregel::audit::{audit_sources, audit_tree, AuditRule};
use std::path::Path;

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn shipped_tree_passes_the_shipped_manifest() {
    let root = crate_root();
    let report = audit_tree(root, &root.join("audit/orderings.toml")).unwrap();
    assert!(
        report.ok(),
        "pallas-audit violations in the shipped tree:\n{}",
        report.render()
    );
    assert!(
        report.warnings.is_empty(),
        "stale manifest entries:\n{}",
        report.render()
    );
    // Sanity: the audit actually saw the tree, not an empty dir.
    assert!(report.files_scanned > 20, "only {} files", report.files_scanned);
    assert!(report.unsafe_sites >= 11, "only {} unsafe", report.unsafe_sites);
    assert!(report.ordering_uses >= 50, "only {} orderings", report.ordering_uses);
}

#[test]
fn missing_manifest_is_a_readable_error() {
    let root = crate_root();
    let err = audit_tree(root, &root.join("audit/nope.toml")).unwrap_err();
    assert!(err.contains("nope.toml"), "unhelpful error: {err}");
}

fn run_fixture(rel: &str, src: &str) -> ipregel::audit::AuditReport {
    audit_sources(&[(rel.to_string(), src.to_string())], &Manifest::default())
}

#[test]
fn fixture_unsafe_without_safety_names_file_and_line() {
    let src = "\
pub fn fill(dst: &mut [u8]) {
    let p = dst.as_mut_ptr();
    unsafe { std::ptr::write_bytes(p, 0, dst.len()) };
}
";
    let r = run_fixture("src/fixture.rs", src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    let d = &r.violations[0];
    assert_eq!(d.rule, AuditRule::UnsafeNeedsSafety);
    assert_eq!((d.file.as_str(), d.line), ("src/fixture.rs", 3));
}

#[test]
fn fixture_safety_comment_may_be_a_multi_line_paragraph() {
    let src = "\
pub fn fill(dst: &mut [u8]) {
    let p = dst.as_mut_ptr();
    // SAFETY: `p` comes from a live &mut slice, the write stays within
    // `dst.len()` bytes, and zero is a valid value for u8 — so the
    // write touches only memory we exclusively borrow.
    unsafe { std::ptr::write_bytes(p, 0, dst.len()) };
}
";
    let r = run_fixture("src/fixture.rs", src);
    assert!(r.ok(), "{}", r.render());
}

#[test]
fn fixture_unlisted_ordering_is_flagged_with_symbol() {
    let m = Manifest::parse(
        "[[site]]\nfile = \"src/fixture.rs\"\nsymbol = \"publish\"\n\
         orderings = [\"Release\"]\nwhy = \"publication store\"\n",
    )
    .unwrap();
    let src = "\
use std::sync::atomic::{AtomicU64, Ordering};
pub fn publish(a: &AtomicU64) {
    a.store(1, Ordering::Relaxed);
}
";
    let r = audit_sources(&[("src/fixture.rs".to_string(), src.to_string())], &m);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    let d = &r.violations[0];
    assert_eq!(d.rule, AuditRule::UnlistedOrdering);
    assert_eq!((d.file.as_str(), d.line), ("src/fixture.rs", 3));
    assert!(d.message.contains("publish"), "no symbol in: {}", d.message);
    assert!(d.message.contains("Release"), "no allowed list in: {}", d.message);
}

#[test]
fn fixture_uncovered_file_reports_missing_entry() {
    let src = "\
use std::sync::atomic::{AtomicU64, Ordering};
pub fn bump(a: &AtomicU64) {
    a.fetch_add(1, Ordering::SeqCst);
}
";
    let r = run_fixture("src/fixture.rs", src);
    assert_eq!(r.violations.len(), 1);
    assert_eq!(r.violations[0].rule, AuditRule::UnlistedOrdering);
    assert!(r.violations[0].message.contains("no manifest entry"));
}

#[test]
fn fixture_static_mut_is_flagged() {
    let src = "static mut GLOBAL_SCRATCH: [u64; 4] = [0; 4];\n";
    let r = run_fixture("src/fixture.rs", src);
    assert_eq!(r.violations.len(), 1);
    let d = &r.violations[0];
    assert_eq!(d.rule, AuditRule::StaticMut);
    assert_eq!(d.line, 1);
}

#[test]
fn fixture_unwrap_in_hot_path_is_flagged_only_there() {
    let src = "\
pub fn collect(v: Option<u64>) -> u64 {
    v.unwrap()
}
";
    // Deny-listed file: violation at the unwrap line.
    let r = run_fixture("src/combine/strategy.rs", src);
    assert_eq!(r.violations.len(), 1, "{}", r.render());
    let d = &r.violations[0];
    assert_eq!(d.rule, AuditRule::PanicInHotPath);
    assert_eq!(d.line, 2);
    // The same code outside the hot paths is fine.
    assert!(run_fixture("src/exp/fixture.rs", src).ok());
    // And the escape hatch silences it when justified.
    let allowed = "\
pub fn collect(v: Option<u64>) -> u64 {
    // audit:allow(panic): configuration invariant validated at startup.
    v.unwrap()
}
";
    assert!(run_fixture("src/combine/strategy.rs", allowed).ok());
}

#[test]
fn fixture_strings_and_comments_never_trip_rules() {
    let src = r##"
pub fn describe() -> &'static str {
    // unsafe static mut Ordering::Relaxed .unwrap() — commentary only
    "unsafe { static mut X } Ordering::AcqRel .unwrap() .expect(msg)"
}
pub fn raw() -> &'static str {
    r#"static mut Y: u8 = 0; Ordering::SeqCst"#
}
"##;
    let r = run_fixture("src/combine/slot.rs", src);
    assert!(r.ok(), "{}", r.render());
    assert_eq!(r.ordering_uses, 0);
}

#[test]
fn fixture_stale_manifest_entry_warns_with_manifest_line() {
    let m = Manifest::parse(
        "# stale site below\n[[site]]\nfile = \"src/gone.rs\"\nsymbol = \"f\"\n\
         orderings = [\"SeqCst\"]\nwhy = \"obsolete\"\n",
    )
    .unwrap();
    let r = audit_sources(&[("src/live.rs".to_string(), "fn f() {}\n".to_string())], &m);
    assert!(r.ok());
    assert_eq!(r.warnings.len(), 1);
    let w = &r.warnings[0];
    assert_eq!(w.rule, AuditRule::StaleManifestEntry);
    assert_eq!(w.file, "audit/orderings.toml");
    assert_eq!(w.line, 2, "should point at the [[site]] header line");
}

#[test]
fn fixture_test_modules_are_exempt_from_the_panic_rule() {
    let src = "\
pub fn real(v: Option<u64>) -> Option<u64> {
    v
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        super::real(Some(3)).unwrap();
    }
}
";
    assert!(run_fixture("src/combine/slot.rs", src).ok());
}
