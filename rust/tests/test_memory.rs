//! Memory-plane integration tests (DESIGN.md §2.12): the compressed and
//! out-of-core row backings must be **bit-invisible** — identical values
//! AND identical superstep traces — across the optimisation grid,
//! through dynamic mutation batches and serving-layer snapshots, while
//! the residency counters prove blocks actually decode, stream and
//! evict. Row storage is an execution knob like layout or scheduling:
//! nothing a program can observe may depend on it.

use ipregel::algos::query::EgoNetBfs;
use ipregel::algos::{ConnectedComponents, PageRank, Sssp};
use ipregel::engine::{EngineConfig, GraphSession, RunOptions};
use ipregel::graph::csr::Csr;
use ipregel::graph::dynamic::{DynamicGraph, MutationSet};
use ipregel::graph::{gen, io, RowMode, RowPolicy};
use ipregel::metrics::RunMetrics;
use ipregel::sched::Schedule;
use ipregel::serve::{AdmissionController, QueryServer, QuerySpec};
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("ipregel_mem_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// The three row backings of one logical graph. The external arena file
/// lives in `dir` so the caller controls cleanup.
fn backings(g: &Csr, dir: &std::path::Path, block: usize) -> Vec<(&'static str, Csr)> {
    vec![
        ("raw", g.clone()),
        ("compressed", g.clone().compress(block)),
        (
            "external",
            io::externalize(g, &dir.join(format!("b{block}.ipgc")), block).unwrap(),
        ),
    ]
}

/// The observable superstep trace: who ran and what was delivered, per
/// superstep. Wall-clock fields are excluded (they are the one thing a
/// backing *is* allowed to change).
fn trace_of(m: &RunMetrics) -> Vec<(usize, u64)> {
    m.supersteps
        .iter()
        .map(|s| (s.active_vertices, s.messages))
        .collect()
}

/// A grid wide enough to cross the backings with every substrate the
/// engine has: flat and sharded, scan and list, static and edge-centric
/// cuts, work-stealing, and the adaptive controller.
fn grid() -> Vec<EngineConfig> {
    vec![
        EngineConfig::default().threads(1),
        EngineConfig::default().threads(4),
        EngineConfig::default().threads(4).bypass(true),
        EngineConfig::default()
            .threads(4)
            .schedule(Schedule::EdgeCentric),
        EngineConfig::default().threads(4).shards(3),
        EngineConfig::default().threads(4).shards(3).steal(true),
        EngineConfig::default().threads(4).shards(3).adaptive(true),
        EngineConfig::default().threads(4).adaptive(true),
    ]
}

#[test]
fn values_and_traces_identical_across_backings_and_grid() {
    let g = gen::rmat(8, 5, 0.57, 0.19, 0.19, 41);
    let dir = tmp_dir("grid");
    for block in [7usize, 64] {
        let sets = backings(&g, &dir, block);
        for cfg in grid() {
            // Pull (PageRank) walks in-rows, push (SSSP) walks out-rows;
            // together they decode both directions of every backing.
            let pr = PageRank::default();
            let ss = Sssp::from_hub(&g);
            let mut want_pr: Option<(Vec<f64>, Vec<(usize, u64)>)> = None;
            let mut want_ss: Option<(Vec<u64>, Vec<(usize, u64)>)> = None;
            for (name, gb) in &sets {
                let session = GraphSession::new(gb);
                let a = session.run_with(&pr, RunOptions::new().config(cfg));
                let b = session.run_with(&ss, RunOptions::new().config(cfg));
                match &want_pr {
                    None => want_pr = Some((a.values, trace_of(&a.metrics))),
                    Some((vals, trace)) => {
                        assert_eq!(&a.values, vals, "pr values {name} b{block} {cfg:?}");
                        assert_eq!(
                            &trace_of(&a.metrics),
                            trace,
                            "pr trace {name} b{block} {cfg:?}"
                        );
                    }
                }
                match &want_ss {
                    None => want_ss = Some((b.values, trace_of(&b.metrics))),
                    Some((vals, trace)) => {
                        assert_eq!(&b.values, vals, "sssp values {name} b{block} {cfg:?}");
                        assert_eq!(
                            &trace_of(&b.metrics),
                            trace,
                            "sssp trace {name} b{block} {cfg:?}"
                        );
                    }
                }
                // Plane-backed runs report the plane; raw runs must not.
                let backed = gb.row_plane().is_some();
                assert_eq!(a.metrics.row_plane.is_some(), backed, "{name}");
                if backed {
                    let rp = a.metrics.row_plane.as_ref().unwrap();
                    assert!(rp.decodes > 0, "{name} b{block}: nothing decoded");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mutation_batches_are_backing_invisible_through_compaction() {
    let dir = tmp_dir("dyn");
    let base = gen::rmat(7, 4, 0.57, 0.19, 0.19, 9);
    let variants = backings(&base, &dir, 16);
    // Drive each backing's DynamicGraph through the same mutation
    // rounds with a spill threshold low enough to force a compaction —
    // which must re-apply the row backing (`Csr::with_backing`) and
    // stay invisible.
    let mut results: Vec<Vec<Vec<u32>>> = Vec::new();
    for (_name, gb) in variants {
        let mut dg = DynamicGraph::with_spill_threshold(gb, 40);
        let mut per_round = Vec::new();
        for round in 0..4u32 {
            let mut m = MutationSet::new();
            for k in 0..12u32 {
                let s = (round * 31 + k * 7) % 128;
                let d = (round * 17 + k * 13 + 1) % 128;
                if s != d {
                    m.insert_undirected(s, d);
                }
            }
            dg.apply(&m);
            let r = GraphSession::new(dg.graph()).run(&ConnectedComponents);
            per_round.push(r.values);
        }
        assert!(
            dg.stats().compactions > 0,
            "spill threshold 40 must force at least one compaction"
        );
        results.push(per_round);
    }
    assert_eq!(results[0], results[1], "compressed diverged from raw");
    assert_eq!(results[0], results[2], "external diverged from raw");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serving_snapshots_time_travel_over_an_external_backing() {
    let dir = tmp_dir("serve");
    let g = gen::rmat(7, 4, 0.57, 0.19, 0.19, 23);
    let ext = io::externalize(&g, &dir.join("serve.ipgc"), 32).unwrap();
    let cfg = EngineConfig::default().threads(2);
    let raw_server = QueryServer::with_config(g, cfg, AdmissionController::new(2));
    let ext_server = QueryServer::with_config(ext, cfg, AdmissionController::new(2));
    let p = EgoNetBfs { root: 3, radius: 2 };
    let spec = QuerySpec::interactive();
    let before_raw = raw_server.execute(&p, &spec).unwrap();
    let before_ext = ext_server.execute(&p, &spec).unwrap();
    assert_eq!(before_raw.values, before_ext.values);

    // Pin the pre-mutation epoch, then mutate both servers identically.
    let pinned = ext_server.pin_current();
    let mut m = MutationSet::new();
    for k in 0..8u32 {
        m.insert_undirected(3 + k, 90 + k);
    }
    raw_server.apply_mutations(&m);
    ext_server.apply_mutations(&m);

    // Time-travel read off the arena-backed snapshot: bit-identical to
    // the pre-mutation answer even though the current graph moved on.
    let old = ext_server.execute_on(&pinned, &p, &spec).unwrap();
    assert_eq!(old.values, before_ext.values, "snapshot isolation broken");
    let after_raw = raw_server.execute(&p, &spec).unwrap();
    let after_ext = ext_server.execute(&p, &spec).unwrap();
    assert_eq!(after_raw.values, after_ext.values);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn oocore_residency_budget_streams_and_evicts() {
    let dir = tmp_dir("oocore");
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 5);
    let ext = io::externalize(&g, &dir.join("res.ipgc"), 16).unwrap();
    let plane = ext.row_plane().unwrap();
    assert_eq!(plane.mode(), RowMode::External);
    plane.set_policy(RowPolicy {
        resident_blocks: Some(2),
        cold_rounds: None,
    });
    let raw = GraphSession::new(&g).run(&PageRank::default());
    let r = GraphSession::new(&ext).run(&PageRank::default());
    assert_eq!(raw.values, r.values);
    let rp = r.metrics.row_plane.expect("plane-backed run reports stats");
    // Every superstep touches most blocks; the 2-block budget forces
    // barrier eviction and re-faulting — the streaming working set.
    assert!(rp.row_faults > plane.num_blocks() as u64, "no streaming");
    assert!(rp.evictions > 0, "budget of 2 never evicted");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compressed_cold_rounds_recycle_scratch_on_a_moving_frontier() {
    // A long path walked by SSSP: the frontier sweeps forward one block
    // at a time, so earlier blocks go cold and a cold_rounds=1 policy
    // must recycle them (and re-decode identically if ever revisited).
    let g = gen::path(512);
    let gc = g.clone().compress(16);
    gc.row_plane()
        .unwrap()
        .set_policy(RowPolicy {
            resident_blocks: None,
            cold_rounds: Some(1),
        });
    let p = Sssp { source: 0 };
    let want = GraphSession::new(&g).run_with(
        &p,
        RunOptions::new().config(EngineConfig::default().bypass(true)),
    );
    let got = GraphSession::new(&gc).run_with(
        &p,
        RunOptions::new().config(EngineConfig::default().bypass(true)),
    );
    assert_eq!(want.values, got.values);
    let rp = got.metrics.row_plane.expect("plane stats");
    assert!(rp.evictions > 0, "cold frontier blocks never recycled");
    assert_eq!(trace_of(&want.metrics), trace_of(&got.metrics));
}

#[test]
fn adaptive_identity_holds_with_an_active_retention_policy() {
    // The adaptive session installs the decision table's cold_rounds on
    // the plane; eviction + re-decode mid-run must stay bit-invisible,
    // including the per-superstep trace.
    let g = gen::rmat(8, 4, 0.57, 0.19, 0.19, 61);
    let gc = g.clone().compress(32);
    let cfg = EngineConfig::default().threads(4).adaptive(true);
    let p = Sssp::from_hub(&g);
    let a = GraphSession::new(&g).run_with(&p, RunOptions::new().config(cfg));
    let b = GraphSession::new(&gc).run_with(&p, RunOptions::new().config(cfg));
    assert_eq!(a.values, b.values);
    assert_eq!(trace_of(&a.metrics), trace_of(&b.metrics));
    assert!(
        gc.row_plane().unwrap().policy().cold_rounds.is_some(),
        "adaptive run must install the retention band"
    );
}
